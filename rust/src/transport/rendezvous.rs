//! Elastic rendezvous: how separate OS processes discover each other,
//! agree on a **membership epoch**, and build the real-TCP mesh from
//! exchanged addresses instead of in-process loopback pairing.
//!
//! The protocol is three control messages over the existing v2 frame
//! codec ([`PayloadKind::Control`] + [`WirePhase::Rendezvous`], epoch in
//! the frame's `step` field):
//!
//! * **JOIN** — a rank connects to the coordinator and announces the
//!   address of its own mesh listener, plus the rank it held in the
//!   previous epoch (if any) and the last step it completed.
//! * **WELCOME** — once the coordinator decides an epoch is complete it
//!   answers every pending member on its join connection: the new world
//!   size, the member's new rank, the previous world size, which
//!   previous-epoch ranks departed, and the full roster of mesh
//!   addresses.  Survivors are ordered by their previous rank (so the
//!   EC re-shard in [`crate::optim::reshard`] is deterministic) and
//!   fresh joiners are appended in arrival order.
//! * **HELLO** — mesh build: each rank dials every *lower* rank's
//!   listener and identifies itself with a HELLO carrying its rank and
//!   the epoch.  The acceptor rejects HELLOs from any other epoch, so a
//!   stale dialer from a dead mesh generation cannot splice into the
//!   new one; every epoch runs on entirely fresh sockets.
//!
//! Epoch formation rule: epoch 1 forms when all `world` expected ranks
//! have joined.  Later epochs form either when every member of the last
//! epoch (or more — late joiners ride along) is back, or when at least
//! `min_world` members are pending and no new JOIN has arrived for a
//! quiet `window` — a SIGKILLed rank never rejoins, so survivors form
//! the M−1 epoch after one window.  That window is the rendezvous term
//! of the bounded epoch-change window modeled by
//! [`crate::netsim::epoch_change_window_bound`].

use std::io::Write;
use std::net::{
    Ipv4Addr, SocketAddr, SocketAddrV4, TcpListener, TcpStream,
};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::util::error::{Error, Result};

use super::frame::{self, PayloadKind, WirePhase};
use super::{TcpOptions, TcpTransport};

/// Frame `rank` tag used before a rank is assigned (JOIN) and by the
/// coordinator itself (WELCOME).
const NO_RANK: u16 = 0xFFFF;

/// Payload tag bytes of the three rendezvous messages.
const TAG_JOIN: u8 = 0x01;
const TAG_WELCOME: u8 = 0x02;
const TAG_HELLO: u8 = 0x03;

/// Poll slice of the coordinator accept loop and the mesh accept loop.
const POLL: Duration = Duration::from_millis(2);

/// Retry backoff while dialing a listener that is not up yet.
const DIAL_BACKOFF: Duration = Duration::from_millis(20);

/// Coordinator policy knobs.
#[derive(Debug, Clone)]
pub struct RendezvousOptions {
    /// Ranks epoch 1 waits for.
    pub world: usize,
    /// Fewest ranks a later epoch may form with.
    pub min_world: usize,
    /// Quiet period after the last JOIN before a partial (`< last
    /// world`) epoch forms — the time budget a slow survivor has to
    /// rejoin before being counted out.
    pub window: Duration,
    /// Read/write timeout on one coordinator connection.
    pub join_timeout: Duration,
}

impl RendezvousOptions {
    /// Defaults for an initial world of `world`: later epochs may shrink
    /// by one (but never below one rank), 2 s quiet window, 10 s per
    /// connection.
    pub fn new(world: usize) -> Self {
        RendezvousOptions {
            world,
            min_world: world.saturating_sub(1).max(1),
            window: Duration::from_secs(2),
            join_timeout: Duration::from_secs(10),
        }
    }
}

/// What one rank learns from a WELCOME: its place in the new epoch and
/// everything needed to build the mesh and re-shard optimizer state.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Membership {
    /// Monotonic epoch number, starting at 1.
    pub epoch: u32,
    /// This rank's position in the new epoch.
    pub rank: usize,
    /// Ranks in the new epoch.
    pub world: usize,
    /// World size of the previous epoch (0 for epoch 1).
    pub prev_world: usize,
    /// Previous-epoch ranks that did not rejoin (ascending).
    pub departed: Vec<usize>,
    /// Previous-epoch ranks that did rejoin (ascending) — new rank `i <
    /// survivors.len()` is the member that held `survivors[i]`, exactly
    /// the order [`crate::optim::reshard::reshard_ec`] expects.
    pub survivors: Vec<usize>,
    /// Mesh listener of every rank, indexed by new rank.
    pub peers: Vec<SocketAddrV4>,
}

// ---- wire codecs -----------------------------------------------------------

fn push_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_addr(buf: &mut Vec<u8>, addr: SocketAddrV4) {
    buf.extend_from_slice(&addr.ip().octets());
    push_u16(buf, addr.port());
}

/// Bounds-checked little-endian reads over a payload cursor.
struct Cursor<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(data: &'a [u8]) -> Self {
        Cursor { data, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.data.len());
        let end = end.ok_or_else(|| {
            Error::msg("rendezvous payload truncated")
        })?;
        let s = &self.data[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn addr(&mut self) -> Result<SocketAddrV4> {
        let ip = self.take(4)?;
        let port = self.u16()?;
        Ok(SocketAddrV4::new(
            Ipv4Addr::new(ip[0], ip[1], ip[2], ip[3]),
            port,
        ))
    }

    fn done(&self) -> Result<()> {
        if self.pos == self.data.len() {
            Ok(())
        } else {
            Err(Error::msg("rendezvous payload has trailing bytes"))
        }
    }
}

/// Read one rendezvous-phase frame off a blocking stream, returning
/// `(epoch, sender rank, payload)`.
fn read_rendezvous(stream: &mut TcpStream) -> Result<(u32, u16, Vec<u8>)> {
    let bytes = frame::read_frame(stream)?
        .ok_or_else(|| Error::msg("rendezvous peer closed"))?;
    let f = frame::decode_frame(&bytes)?;
    if f.kind != PayloadKind::Control || f.phase != WirePhase::Rendezvous {
        return Err(Error::msg("unexpected frame during rendezvous"));
    }
    Ok((f.step, f.rank, f.payload.to_vec()))
}

fn write_rendezvous(
    stream: &mut TcpStream,
    epoch: u32,
    rank: u16,
    payload: &[u8],
) -> Result<()> {
    let f = frame::encode_frame(
        PayloadKind::Control,
        WirePhase::Rendezvous,
        rank,
        epoch,
        payload,
    );
    stream.write_all(&f)?;
    stream.flush()?;
    Ok(())
}

// ---- coordinator -----------------------------------------------------------

/// One rank waiting for the next epoch.
struct Pending {
    stream: TcpStream,
    prev_rank: Option<usize>,
    mesh_addr: SocketAddrV4,
}

/// The rendezvous coordinator: a background listener thread that
/// collects JOINs and answers each complete epoch with WELCOMEs.  It
/// holds no optimizer state — crash-restarting it only delays the next
/// re-formation.
pub struct Coordinator {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Bind `bind` (e.g. `"127.0.0.1:0"`) and start serving epochs.
    pub fn spawn(bind: &str, opts: RendezvousOptions) -> Result<Coordinator> {
        if opts.world == 0 || opts.min_world == 0 {
            return Err(Error::Config(
                "rendezvous world sizes must be nonzero".into(),
            ));
        }
        if opts.min_world > opts.world {
            return Err(Error::Config(format!(
                "rendezvous min_world {} exceeds world {}",
                opts.min_world, opts.world
            )));
        }
        let listener = TcpListener::bind(bind)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("obtw-rendezvous".into())
            .spawn(move || serve(listener, opts, flag))
            .map_err(Error::Io)?;
        Ok(Coordinator { addr, stop, handle: Some(handle) })
    }

    /// The address ranks pass as `--coordinator`.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// The coordinator loop: accept JOINs, decide epochs, send WELCOMEs.
fn serve(listener: TcpListener, opts: RendezvousOptions, stop: Arc<AtomicBool>) {
    let mut epoch: u32 = 0;
    let mut last_world = opts.world;
    let mut pending: Vec<Pending> = Vec::new();
    // lint: allow(timing): the membership join window is inherently
    // wall-clock; epoch contents stay deterministic once formed.
    let mut last_join = Instant::now();
    while !stop.load(Ordering::SeqCst) {
        // Drain the accept queue.
        loop {
            match listener.accept() {
                Ok((mut stream, _)) => {
                    if let Ok(p) = read_join(&mut stream, opts.join_timeout) {
                        // A rank that rejoins twice (crash between JOIN
                        // and WELCOME) supersedes its older entry.
                        if let Some(prev) = p.prev_rank {
                            pending.retain(|q| q.prev_rank != Some(prev));
                        }
                        pending.push(p);
                        // lint: allow(timing): restart the join window.
                        last_join = Instant::now();
                    }
                }
                Err(ref e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    break
                }
                Err(_) => break,
            }
        }
        let target = if epoch == 0 { opts.world } else { last_world };
        // Later epochs need at least one survivor of the previous one —
        // a parked fresh joiner alone must never form a rogue epoch
        // while the original mesh is still healthy.
        let anchored = epoch == 0
            || pending.iter().any(|p| p.prev_rank.is_some());
        let full = pending.len() >= target;
        let partial = epoch > 0
            && pending.len() >= opts.min_world
            && last_join.elapsed() >= opts.window;
        if (full || partial) && anchored && !pending.is_empty() {
            epoch += 1;
            let members = std::mem::take(&mut pending);
            last_world =
                form_epoch(epoch, last_world, members, epoch == 1);
        }
        std::thread::sleep(POLL);
    }
}

/// Read one JOIN off a fresh coordinator connection.
fn read_join(stream: &mut TcpStream, timeout: Duration) -> Result<Pending> {
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    let (_, rank, payload) = read_rendezvous(stream)?;
    if rank != NO_RANK {
        return Err(Error::msg("JOIN must not carry a rank"));
    }
    let mut c = Cursor::new(&payload);
    if c.u8()? != TAG_JOIN {
        return Err(Error::msg("expected JOIN"));
    }
    let has_prev = c.u8()? != 0;
    let prev_rank = c.u16()?;
    let _last_step = c.u64()?;
    let mesh_addr = c.addr()?;
    c.done()?;
    Ok(Pending {
        stream: stream.try_clone()?,
        prev_rank: has_prev.then_some(prev_rank as usize),
        mesh_addr,
    })
}

/// Assign ranks and send every member its WELCOME.  Returns the new
/// world size.  Survivors sorted by previous rank come first — the
/// deterministic order the EC re-shard keys off — then fresh joiners in
/// arrival order.
fn form_epoch(
    epoch: u32,
    prev_world: usize,
    mut members: Vec<Pending>,
    first: bool,
) -> usize {
    members.sort_by_key(|p| match p.prev_rank {
        Some(r) => (0, r),
        None => (1, usize::MAX),
    });
    let world = members.len();
    let prev_world = if first { 0 } else { prev_world };
    let survivors: Vec<usize> =
        members.iter().filter_map(|p| p.prev_rank).collect();
    let departed: Vec<usize> = (0..prev_world)
        .filter(|r| !survivors.contains(r))
        .collect();
    let mut roster = Vec::new();
    for p in &members {
        push_u16(
            &mut roster,
            p.prev_rank.map_or(NO_RANK, |r| r as u16),
        );
        push_addr(&mut roster, p.mesh_addr);
    }
    for (rank, p) in members.iter_mut().enumerate() {
        let mut payload = vec![TAG_WELCOME];
        push_u16(&mut payload, world as u16);
        push_u16(&mut payload, rank as u16);
        push_u16(&mut payload, prev_world as u16);
        push_u16(&mut payload, departed.len() as u16);
        for &d in &departed {
            push_u16(&mut payload, d as u16);
        }
        payload.extend_from_slice(&roster);
        // A member that died between JOIN and WELCOME fails here; its
        // peers will fail the mesh build and re-enter rendezvous.
        let _ = write_rendezvous(&mut p.stream, epoch, NO_RANK, &payload);
    }
    world
}

// ---- client side -----------------------------------------------------------

/// Announce this rank to the coordinator and block until the next epoch
/// forms.  `mesh_addr` is the caller's own (already-bound) mesh
/// listener; `prev_rank` is the rank held in the previous epoch, `None`
/// for a fresh joiner; `last_step` is informational (logged by the
/// operator, not consumed by the protocol).  `timeout` bounds the whole
/// wait: connect retries + the coordinator's quiet window.
pub fn join(
    coordinator: SocketAddr,
    mesh_addr: SocketAddrV4,
    prev_rank: Option<usize>,
    last_step: u64,
    timeout: Duration,
) -> Result<Membership> {
    // lint: allow(timing): dial/retry deadline against a live
    // coordinator socket.
    let deadline = Instant::now() + timeout;
    let mut stream = loop {
        match TcpStream::connect_timeout(
            &coordinator,
            DIAL_BACKOFF.max(Duration::from_millis(100)),
        ) {
            Ok(s) => break s,
            Err(e) => {
                // lint: allow(timing): same dial deadline check.
                if Instant::now() >= deadline {
                    return Err(Error::Io(e));
                }
                std::thread::sleep(DIAL_BACKOFF);
            }
        }
    };
    stream.set_nodelay(true)?;
    let mut payload = vec![TAG_JOIN];
    payload.push(u8::from(prev_rank.is_some()));
    push_u16(&mut payload, prev_rank.unwrap_or(0) as u16);
    payload.extend_from_slice(&last_step.to_le_bytes());
    push_addr(&mut payload, mesh_addr);
    write_rendezvous(&mut stream, 0, NO_RANK, &payload)?;
    // lint: allow(timing): remaining read budget under the deadline.
    let remaining = deadline.saturating_duration_since(Instant::now());
    stream.set_read_timeout(Some(remaining.max(POLL)))?;
    let (epoch, _, payload) = read_rendezvous(&mut stream)?;
    parse_welcome(epoch, &payload)
}

fn parse_welcome(epoch: u32, payload: &[u8]) -> Result<Membership> {
    let mut c = Cursor::new(payload);
    if c.u8()? != TAG_WELCOME {
        return Err(Error::msg("expected WELCOME"));
    }
    let world = c.u16()? as usize;
    let rank = c.u16()? as usize;
    let prev_world = c.u16()? as usize;
    let n_departed = c.u16()? as usize;
    let mut departed = Vec::with_capacity(n_departed);
    for _ in 0..n_departed {
        departed.push(c.u16()? as usize);
    }
    let mut survivors = Vec::new();
    let mut peers = Vec::with_capacity(world);
    for _ in 0..world {
        let prev = c.u16()?;
        if prev != NO_RANK {
            survivors.push(prev as usize);
        }
        peers.push(c.addr()?);
    }
    c.done()?;
    if rank >= world || epoch == 0 {
        return Err(Error::msg("malformed WELCOME"));
    }
    Ok(Membership {
        epoch,
        rank,
        world,
        prev_world,
        departed,
        survivors,
        peers,
    })
}

/// Build this epoch's full-duplex mesh from the WELCOME roster: dial
/// every lower rank's listener (identifying with an epoch-tagged HELLO),
/// accept one validated HELLO from every higher rank, then assemble the
/// streams into a [`TcpTransport`] endpoint.  A HELLO from any other
/// epoch is dropped — a stale dialer from a dead mesh generation cannot
/// splice into the new one.
pub fn connect_mesh(
    m: &Membership,
    listener: &TcpListener,
    opts: &TcpOptions,
) -> Result<TcpTransport> {
    // lint: allow(timing): mesh-formation dial deadline.
    let deadline = Instant::now() + opts.recv_timeout;
    let mut streams: Vec<(usize, TcpStream)> =
        Vec::with_capacity(m.world.saturating_sub(1));
    // Dial the lower ranks.
    for peer in 0..m.rank {
        let addr = SocketAddr::V4(m.peers[peer]);
        let mut stream = loop {
            match TcpStream::connect_timeout(&addr, DIAL_BACKOFF.max(POLL)) {
                Ok(s) => break s,
                Err(e) => {
                    // lint: allow(timing): same dial deadline check.
                    if Instant::now() >= deadline {
                        return Err(Error::Io(e));
                    }
                    std::thread::sleep(DIAL_BACKOFF);
                }
            }
        };
        stream.set_nodelay(true)?;
        write_rendezvous(
            &mut stream,
            m.epoch,
            m.rank as u16,
            &[TAG_HELLO],
        )?;
        streams.push((peer, stream));
    }
    // Accept the higher ranks.
    listener.set_nonblocking(true)?;
    let mut missing: Vec<bool> = (0..m.world).map(|r| r > m.rank).collect();
    while missing.iter().any(|&w| w) {
        match listener.accept() {
            Ok((mut stream, _)) => {
                stream.set_nonblocking(false)?;
                stream.set_read_timeout(Some(opts.recv_timeout))?;
                match read_rendezvous(&mut stream) {
                    Ok((epoch, rank, payload))
                        if epoch == m.epoch
                            && payload == [TAG_HELLO]
                            && (rank as usize) < m.world
                            && (rank as usize) > m.rank
                            && missing[rank as usize] =>
                    {
                        stream.set_read_timeout(None)?;
                        missing[rank as usize] = false;
                        streams.push((rank as usize, stream));
                    }
                    // Stale epoch / malformed hello: drop the stream.
                    _ => {}
                }
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                // lint: allow(timing): HELLO-accept deadline check.
                if Instant::now() >= deadline {
                    return Err(Error::msg(
                        "mesh build timed out waiting for peer HELLOs",
                    ));
                }
                std::thread::sleep(POLL);
            }
            Err(e) => return Err(Error::Io(e)),
        }
    }
    TcpTransport::from_streams(m.rank, m.world, streams, opts)
}

/// Bind a fresh mesh listener for one epoch attempt.  Bound *before*
/// [`join`] so the JOIN can carry a live address.
pub fn bind_mesh_listener() -> Result<(TcpListener, SocketAddrV4)> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = match listener.local_addr()? {
        SocketAddr::V4(a) => a,
        SocketAddr::V6(_) => {
            return Err(Error::Config(
                "rendezvous mesh requires an IPv4 listener".into(),
            ))
        }
    };
    Ok((listener, addr))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::frame::decode_frame;
    use crate::transport::Transport;

    fn quick_opts(world: usize, window_ms: u64) -> RendezvousOptions {
        RendezvousOptions {
            world,
            min_world: world.saturating_sub(1).max(1),
            window: Duration::from_millis(window_ms),
            join_timeout: Duration::from_secs(5),
        }
    }

    fn join_fresh(
        coord: SocketAddr,
    ) -> (Membership, TcpListener) {
        let (listener, addr) = bind_mesh_listener().unwrap();
        let m =
            join(coord, addr, None, 0, Duration::from_secs(10)).unwrap();
        (m, listener)
    }

    #[test]
    #[cfg_attr(miri, ignore = "real sockets are unsupported under Miri")]
    fn epoch_one_forms_when_all_ranks_join() {
        let coord =
            Coordinator::spawn("127.0.0.1:0", quick_opts(3, 100)).unwrap();
        let addr = coord.addr();
        let handles: Vec<_> = (0..3)
            .map(|_| std::thread::spawn(move || join_fresh(addr).0))
            .collect();
        let mut members: Vec<Membership> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        members.sort_by_key(|m| m.rank);
        let ranks: Vec<usize> = members.iter().map(|m| m.rank).collect();
        assert_eq!(ranks, vec![0, 1, 2]);
        for m in &members {
            assert_eq!(m.epoch, 1);
            assert_eq!(m.world, 3);
            assert_eq!(m.prev_world, 0);
            assert!(m.departed.is_empty());
            assert!(m.survivors.is_empty());
            assert_eq!(m.peers, members[0].peers);
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "real sockets are unsupported under Miri")]
    fn survivors_reform_at_m_minus_one_after_the_quiet_window() {
        let coord =
            Coordinator::spawn("127.0.0.1:0", quick_opts(3, 100)).unwrap();
        let addr = coord.addr();
        let handles: Vec<_> = (0..3)
            .map(|_| std::thread::spawn(move || join_fresh(addr).0))
            .collect();
        let first: Vec<Membership> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        // Ranks 1 and 2 of epoch 1 rejoin; rank 0 is "dead".
        let survivors: Vec<usize> = first
            .iter()
            .map(|m| m.rank)
            .filter(|&r| r != 0)
            .collect();
        let handles: Vec<_> = survivors
            .into_iter()
            .map(|prev| {
                std::thread::spawn(move || {
                    let (_, mesh) = bind_mesh_listener().unwrap();
                    join(
                        addr,
                        mesh,
                        Some(prev),
                        7,
                        Duration::from_secs(10),
                    )
                    .unwrap()
                })
            })
            .collect();
        let mut second: Vec<Membership> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        second.sort_by_key(|m| m.rank);
        for m in &second {
            assert_eq!(m.epoch, 2);
            assert_eq!(m.world, 2);
            assert_eq!(m.prev_world, 3);
            assert_eq!(m.departed, vec![0]);
            assert_eq!(m.survivors, vec![1, 2]);
        }
        // Survivor order: previous rank 1 → new rank 0, 2 → 1.
        assert_eq!(second[0].rank, 0);
        assert_eq!(second[1].rank, 1);
    }

    #[test]
    #[cfg_attr(miri, ignore = "real sockets are unsupported under Miri")]
    fn rendezvous_mesh_carries_frames_between_processes_worth_of_ranks() {
        let coord =
            Coordinator::spawn("127.0.0.1:0", quick_opts(2, 100)).unwrap();
        let addr = coord.addr();
        let worker = |tag: f32| {
            move || {
                let (m, listener) = join_fresh(addr);
                let opts = TcpOptions {
                    recv_timeout: Duration::from_secs(10),
                    ..TcpOptions::default()
                };
                let mut ep = connect_mesh(&m, &listener, &opts).unwrap();
                let peer = 1 - m.rank;
                let payload = frame::f32_payload(&[tag + m.rank as f32]);
                let f = frame::encode_frame(
                    PayloadKind::F32Plain,
                    WirePhase::AllToAll,
                    m.rank as u16,
                    m.epoch,
                    &payload,
                );
                ep.send(peer, &f).unwrap();
                let bytes = ep.recv(peer).unwrap();
                let got = decode_frame(&bytes).unwrap();
                assert_eq!(got.rank as usize, peer);
                assert_eq!(got.step, m.epoch);
                m.rank
            }
        };
        let a = std::thread::spawn(worker(10.0));
        let b = std::thread::spawn(worker(10.0));
        let mut ranks = vec![a.join().unwrap(), b.join().unwrap()];
        ranks.sort_unstable();
        assert_eq!(ranks, vec![0, 1]);
    }
}
