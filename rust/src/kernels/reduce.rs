//! Pairwise (tree) summation for the warmup-phase full-precision average.
//!
//! The reference `PlainPath::Reference` loop in [`crate::comm::plain`] is
//! element-outer / worker-inner: per element it walks all `n` workers
//! through one serial f64 accumulator — an n-deep dependency chain per
//! element and no vectorization.  The kernel here inverts that:
//!
//! * **cache-blocked** — elements are processed in [`REDUCE_BLK`]-wide
//!   blocks whose f64 accumulator strip stays resident in L1 while every
//!   worker's slice streams through once;
//! * **pairwise (tree) accumulation** — workers are combined as a binary
//!   tree `((w₀‥w_{k/2}) + (w_{k/2}‥w_k))`, the classic pairwise-summation
//!   order, in f64, so the accumulation error is O(log n) — at least as
//!   accurate as the reference's sequential f64 sum;
//! * **lane-parallel** — inside a block every element is independent, so
//!   the add loops vectorize.
//!
//! Because each output element is a pure function of that element across
//! workers, splitting the element range over threads (see
//! `comm::plain::allreduce_average_path`) cannot change any result:
//! thread counts and block boundaries are numerically irrelevant.
//! Against the reference path the result is property-tested equal within
//! 1 ULP (two f64 accumulation orders of ≤ a few dozen f32 terms round to
//! the same f32 except at rounding-boundary ties).

/// Element-block width: 8 KiB of f64 accumulator — resident in L1 along
/// with the f32 input streams.
pub const REDUCE_BLK: usize = 1024;

// lint: hot-path — the deterministic reduction tree; runs per block per
// step over every worker's gradient and must stay allocation-free.
/// Pairwise-tree sum of `inputs[w][offset + i]` over `w` into `acc[i]`.
/// `acc.len()` must be ≤ [`REDUCE_BLK`] (enforced by the temp buffers).
fn tree_sum_block(inputs: &[&[f32]], offset: usize, acc: &mut [f64]) {
    let len = acc.len();
    debug_assert!(len <= REDUCE_BLK);
    match inputs.len() {
        0 => unreachable!("tree_sum_block requires >= 1 worker"),
        1 => {
            let a = &inputs[0][offset..offset + len];
            for i in 0..len {
                acc[i] = a[i] as f64;
            }
        }
        2 => {
            let a = &inputs[0][offset..offset + len];
            let b = &inputs[1][offset..offset + len];
            for i in 0..len {
                acc[i] = a[i] as f64 + b[i] as f64;
            }
        }
        k => {
            let mid = k / 2;
            tree_sum_block(&inputs[..mid], offset, acc);
            let mut tmp = [0.0f64; REDUCE_BLK];
            let t = &mut tmp[..len];
            tree_sum_block(&inputs[mid..], offset, t);
            for i in 0..len {
                acc[i] += t[i];
            }
        }
    }
}

/// Average `inputs[w][offset..offset + out.len()]` over workers into
/// `out`, block by block: pairwise f64 tree sum, then the reference's
/// `sum / n` (in f64) rounded once to f32.
pub fn tree_average_into(inputs: &[&[f32]], offset: usize, out: &mut [f32]) {
    tree_scaled_average_into(inputs, offset, inputs.len() as f64, out);
}

/// [`tree_average_into`] with an arbitrary positive divisor: `out[k] =
/// (pairwise-f64 Σ_w inputs[w][offset + k]) / div`, rounded once to f32.
///
/// The hierarchical allreduce's stage-1 intra-node reduce divides each
/// node's sum by `n / L` (total workers over leader count) instead of the
/// group size, so that the leader-level *unweighted* average of the node
/// tensors is exactly the global mean even when the trailing group is
/// short (non-divisible topologies).
pub fn tree_scaled_average_into(
    inputs: &[&[f32]],
    offset: usize,
    div: f64,
    out: &mut [f32],
) {
    let n = inputs.len();
    assert!(n > 0);
    assert!(div > 0.0);
    let mut acc = [0.0f64; REDUCE_BLK];
    let mut i = 0;
    while i < out.len() {
        let blk = REDUCE_BLK.min(out.len() - i);
        let a = &mut acc[..blk];
        tree_sum_block(inputs, offset + i, a);
        for k in 0..blk {
            out[i + k] = (a[k] / div) as f32;
        }
        i += blk;
    }
}

/// Pairwise-tree f64 sum of `inputs[w][offset + i]` over workers into
/// `acc[i]` (overwriting), for one block of at most [`REDUCE_BLK`]
/// elements.  Public building block for reductions that need the raw f64
/// partial sums — the hierarchical identity-compression path combines
/// per-node block sums in f64 and rounds exactly once.
pub fn tree_sum_into(inputs: &[&[f32]], offset: usize, acc: &mut [f64]) {
    assert!(!inputs.is_empty());
    assert!(acc.len() <= REDUCE_BLK);
    tree_sum_block(inputs, offset, acc);
}
// lint: end

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn exact_small_average() {
        let a = vec![1.0f32, 2.0, 3.0];
        let b = vec![3.0f32, 2.0, 1.0];
        let views: Vec<&[f32]> = vec![&a, &b];
        let mut out = vec![0.0f32; 3];
        tree_average_into(&views, 0, &mut out);
        assert_eq!(out, vec![2.0, 2.0, 2.0]);
    }

    #[test]
    fn single_worker_is_identity() {
        let a: Vec<f32> = (0..100).map(|i| i as f32 * 0.37 - 5.0).collect();
        let views: Vec<&[f32]> = vec![&a];
        let mut out = vec![0.0f32; 100];
        tree_average_into(&views, 0, &mut out);
        assert_eq!(out, a);
    }

    #[test]
    fn offset_slices_the_right_window() {
        let inputs: Vec<Vec<f32>> =
            (0..3).map(|w| (0..50).map(|i| (w * 100 + i) as f32).collect())
                .collect();
        let views: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let mut out = vec![0.0f32; 10];
        tree_average_into(&views, 20, &mut out);
        for (k, &o) in out.iter().enumerate() {
            // mean over w of (w*100 + 20 + k) = 100 + 20 + k
            assert_eq!(o, (120 + k) as f32);
        }
    }

    #[test]
    fn scaled_average_with_worker_count_divisor_is_the_plain_average() {
        // div = n must reproduce tree_average_into bit for bit (the
        // refactor contract: tree_average_into is the div = n special
        // case).
        let base = Rng::new(31);
        let inputs: Vec<Vec<f32>> =
            (0..5).map(|w| base.fork(w as u64).normal_vec(700, 1.0)).collect();
        let views: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let mut plain = vec![0.0f32; 700];
        tree_average_into(&views, 0, &mut plain);
        let mut scaled = vec![0.0f32; 700];
        tree_scaled_average_into(&views, 0, 5.0, &mut scaled);
        assert_eq!(plain, scaled);
    }

    #[test]
    fn scaled_average_divides_by_the_given_factor() {
        let a = vec![2.0f32, 4.0, 6.0];
        let b = vec![4.0f32, 2.0, 0.0];
        let views: Vec<&[f32]> = vec![&a, &b];
        let mut out = vec![0.0f32; 3];
        // sum = (6, 6, 6); div 3 => (2, 2, 2)
        tree_scaled_average_into(&views, 0, 3.0, &mut out);
        assert_eq!(out, vec![2.0, 2.0, 2.0]);
    }

    #[test]
    fn tree_sum_into_matches_sequential_f64() {
        let base = Rng::new(77);
        let inputs: Vec<Vec<f32>> =
            (0..4).map(|w| base.fork(w as u64).normal_vec(100, 1.0)).collect();
        let views: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let mut acc = vec![0.0f64; 40];
        tree_sum_into(&views, 10, &mut acc);
        for (k, &a) in acc.iter().enumerate() {
            let mut expect = 0.0f64;
            for inp in &inputs {
                expect += inp[10 + k] as f64;
            }
            assert!((a - expect).abs() < 1e-9, "k={k}: {a} vs {expect}");
        }
    }

    #[test]
    fn block_boundaries_and_worker_counts() {
        // Straddle REDUCE_BLK and exercise every tree shape 1..=9.
        for &len in &[REDUCE_BLK - 1, REDUCE_BLK, REDUCE_BLK + 1, 2500] {
            for workers in 1..=9usize {
                let base = Rng::new((len + workers) as u64);
                let inputs: Vec<Vec<f32>> = (0..workers)
                    .map(|w| base.fork(w as u64).normal_vec(len, 1.0))
                    .collect();
                let views: Vec<&[f32]> =
                    inputs.iter().map(|v| v.as_slice()).collect();
                let mut out = vec![0.0f32; len];
                tree_average_into(&views, 0, &mut out);
                // f64 sequential reference
                for i in (0..len).step_by(171) {
                    let mut acc = 0.0f64;
                    for inp in &inputs {
                        acc += inp[i] as f64;
                    }
                    let expect = (acc / workers as f64) as f32;
                    let diff = (out[i] - expect).abs() as f64;
                    assert!(
                        diff <= (f32::EPSILON * expect.abs()) as f64 + 1e-12,
                        "len={len} workers={workers} i={i}: {} vs {expect}",
                        out[i]
                    );
                }
            }
        }
    }
}
