//! Fused elementwise kernels for the optimizer hot loops.
//!
//! Every elementwise pass the step loop performs — the full Adam moment +
//! parameter update, the per-worker momentum refresh of the compression
//! stage, and the frozen-variance preconditioned step — funnels through
//! the functions here.  Three ingredients, all std-only:
//!
//! 1. **Fusion** — one pass over the state tensors instead of one per
//!    sub-expression (the Adam step reads `p/m/v/g` once and writes
//!    `p/m/v` once; the momentum refresh produces `β·m̄ + (1−β)·g` straight
//!    into the per-worker buffer, eliminating the `copy_from_slice` +
//!    update double pass).
//! 2. **Fixed-width lanes** — bodies run on [`LANES`]-wide blocks via
//!    `chunks_exact`, so LLVM sees a constant trip count and emits
//!    straight-line SIMD; the sub-lane tail reuses the identical block
//!    body, so tail elements get bit-identical math.
//! 3. **`f32::mul_add`** — the multiply-add chains contract to a single
//!    rounding (hardware FMA where the target has it).
//!
//! The pre-existing scalar loops are preserved verbatim as
//! [`crate::optim::backend::ScalarBackend`]; property tests
//! (here and in `optim::backend`) pin the fused kernels to that executable
//! specification within a few ULP across lengths 0..4096, including every
//! non-multiple-of-`LANES` tail.
//!
//! Multithreaded variants (`*_par`) fan contiguous sub-slices out over
//! [`crate::util::par::par_tasks`]; the kernels are pure elementwise, so
//! the parallel split is bit-identical to the sequential order.

use crate::util::par::{par_tasks, PAR_MIN_LEN};

/// Lane width of the fixed-size inner blocks (8 × f32 = one AVX2 register;
/// wider targets simply unroll two blocks per vector op).
pub const LANES: usize = 8;

/// Bias-correction-free Adam hyperparameters (paper eq. (1); matches the
/// static args baked into the AOT Pallas kernel artifacts).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdamHyper {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
}

impl Default for AdamHyper {
    fn default() -> Self {
        AdamHyper { beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }
}

/// Per-call constants of the fused Adam step, resolved once outside the
/// lane loop.
#[derive(Clone, Copy)]
struct AdamConsts {
    beta1: f32,
    omb1: f32,
    beta2: f32,
    omb2: f32,
    eps: f32,
    lr: f32,
}

impl AdamConsts {
    fn new(h: AdamHyper, lr: f32) -> Self {
        AdamConsts {
            beta1: h.beta1,
            omb1: 1.0 - h.beta1,
            beta2: h.beta2,
            omb2: 1.0 - h.beta2,
            eps: h.eps,
            lr,
        }
    }
}

// lint: hot-path — the fused element kernels (through the compensate
// family) run once per step over every parameter; zero allocation is
// part of their contract.  The `*_par` dispatchers sit outside the
// fences: they build one small task vector per call by design.
#[inline(always)]
fn adam_block(
    c: AdamConsts,
    p: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    g: &[f32],
) {
    for i in 0..g.len() {
        let gi = g[i];
        let mi = c.beta1.mul_add(m[i], c.omb1 * gi);
        let vi = c.beta2.mul_add(v[i], (c.omb2 * gi) * gi);
        m[i] = mi;
        v[i] = vi;
        p[i] -= c.lr * mi / (vi.sqrt() + c.eps);
    }
}

/// Fused Adam step: one pass updates `p`, `m`, `v` in place from `g`.
///
/// `m ← β₁·m + (1−β₁)·g`, `v ← β₂·v + (1−β₂)·g²`,
/// `p ← p − lr·m/(√v + ε)` — with β₂ = 1 the `mul_add` form keeps `v`
/// bitwise frozen (`1·v + 0·g² = v`), preserving the paper's
/// β₂=1 ≡ preconditioned-momentum identity exactly.
pub fn adam_step_fused(
    h: AdamHyper,
    p: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    g: &[f32],
    lr: f32,
) {
    let n = p.len();
    assert!(m.len() == n && v.len() == n && g.len() == n);
    let c = AdamConsts::new(h, lr);
    let split = n - n % LANES;
    let (ph, pt) = p.split_at_mut(split);
    let (mh, mt) = m.split_at_mut(split);
    let (vh, vt) = v.split_at_mut(split);
    let (gh, gt) = g.split_at(split);
    for (((pl, ml), vl), gl) in ph
        .chunks_exact_mut(LANES)
        .zip(mh.chunks_exact_mut(LANES))
        .zip(vh.chunks_exact_mut(LANES))
        .zip(gh.chunks_exact(LANES))
    {
        adam_block(c, pl, ml, vl, gl);
    }
    adam_block(c, pt, mt, vt, gt);
}
// lint: end

/// [`adam_step_fused`] over contiguous sub-slices on up to `threads`
/// scoped threads (bit-identical: the kernel is pure elementwise).
/// Falls back to the sequential kernel below [`PAR_MIN_LEN`].
pub fn adam_step_par(
    threads: usize,
    h: AdamHyper,
    p: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    g: &[f32],
    lr: f32,
) {
    let n = p.len();
    if threads <= 1 || n < PAR_MIN_LEN {
        adam_step_fused(h, p, m, v, g, lr);
        return;
    }
    assert!(m.len() == n && v.len() == n && g.len() == n);
    let blk = n.div_ceil(threads);
    let mut tasks: Vec<(&mut [f32], &mut [f32], &mut [f32], &[f32])> = p
        .chunks_mut(blk)
        .zip(m.chunks_mut(blk))
        .zip(v.chunks_mut(blk))
        .zip(g.chunks(blk))
        .map(|(((pb, mb), vb), gb)| (pb, mb, vb, gb))
        .collect();
    par_tasks(threads, &mut tasks, |t| {
        adam_step_fused(h, t.0, t.1, t.2, t.3, lr)
    });
}

// lint: hot-path — momentum / refresh / precond fused kernels.
#[inline(always)]
fn momentum_block(beta: f32, omb: f32, m: &mut [f32], g: &[f32]) {
    for i in 0..g.len() {
        m[i] = beta.mul_add(m[i], omb * g[i]);
    }
}

/// In-place momentum update `m ← β·m + (1−β)·g`.
pub fn momentum_update_fused(beta: f32, m: &mut [f32], g: &[f32]) {
    let n = m.len();
    assert_eq!(g.len(), n);
    let omb = 1.0 - beta;
    let split = n - n % LANES;
    let (mh, mt) = m.split_at_mut(split);
    let (gh, gt) = g.split_at(split);
    for (ml, gl) in mh.chunks_exact_mut(LANES).zip(gh.chunks_exact(LANES)) {
        momentum_block(beta, omb, ml, gl);
    }
    momentum_block(beta, omb, mt, gt);
}

#[inline(always)]
fn refresh_block(
    beta: f32,
    omb: f32,
    shared: &[f32],
    g: &[f32],
    out: &mut [f32],
) {
    for i in 0..g.len() {
        out[i] = beta.mul_add(shared[i], omb * g[i]);
    }
}

/// Fused momentum **refresh**: `out ← β·shared + (1−β)·g` in a single
/// pass — replaces the `copy_from_slice(shared)` + in-place update double
/// pass of the compression stage (Algorithm 1, line 6).  Bit-identical to
/// that two-pass sequence, since [`momentum_update_fused`] applies the
/// same `mul_add` to the copied values.
pub fn momentum_refresh_fused(
    beta: f32,
    shared: &[f32],
    g: &[f32],
    out: &mut [f32],
) {
    let n = out.len();
    assert!(shared.len() == n && g.len() == n);
    let omb = 1.0 - beta;
    let split = n - n % LANES;
    let (oh, ot) = out.split_at_mut(split);
    let (sh, st) = shared.split_at(split);
    let (gh, gt) = g.split_at(split);
    for ((ol, sl), gl) in oh
        .chunks_exact_mut(LANES)
        .zip(sh.chunks_exact(LANES))
        .zip(gh.chunks_exact(LANES))
    {
        refresh_block(beta, omb, sl, gl, ol);
    }
    refresh_block(beta, omb, st, gt, ot);
}

#[inline(always)]
fn precond_block(
    eps: f32,
    lr: f32,
    p: &mut [f32],
    m: &[f32],
    v_frozen: &[f32],
) {
    for i in 0..p.len() {
        p[i] -= lr * m[i] / (v_frozen[i].sqrt() + eps);
    }
}

/// Preconditioned momentum step `p ← p − lr·m/(√v_frozen + ε)`
/// (Algorithm 1, line 13).
pub fn precond_step_fused(
    eps: f32,
    p: &mut [f32],
    m: &[f32],
    v_frozen: &[f32],
    lr: f32,
) {
    let n = p.len();
    assert!(m.len() == n && v_frozen.len() == n);
    let split = n - n % LANES;
    let (ph, pt) = p.split_at_mut(split);
    let (mh, mt) = m.split_at(split);
    let (vh, vt) = v_frozen.split_at(split);
    for ((pl, ml), vl) in ph
        .chunks_exact_mut(LANES)
        .zip(mh.chunks_exact(LANES))
        .zip(vh.chunks_exact(LANES))
    {
        precond_block(eps, lr, pl, ml, vl);
    }
    precond_block(eps, lr, pt, mt, vt);
}
// lint: end

/// [`precond_step_fused`] over contiguous sub-slices on up to `threads`
/// scoped threads; sequential below [`PAR_MIN_LEN`].
pub fn precond_step_par(
    threads: usize,
    eps: f32,
    p: &mut [f32],
    m: &[f32],
    v_frozen: &[f32],
    lr: f32,
) {
    let n = p.len();
    if threads <= 1 || n < PAR_MIN_LEN {
        precond_step_fused(eps, p, m, v_frozen, lr);
        return;
    }
    assert!(m.len() == n && v_frozen.len() == n);
    let blk = n.div_ceil(threads);
    let mut tasks: Vec<(&mut [f32], &[f32], &[f32])> = p
        .chunks_mut(blk)
        .zip(m.chunks(blk))
        .zip(v_frozen.chunks(blk))
        .map(|((pb, mb), vb)| (pb, mb, vb))
        .collect();
    par_tasks(threads, &mut tasks, |t| {
        precond_step_fused(eps, t.0, t.1, t.2, lr)
    });
}

// lint: hot-path — EC compensate kernels (the per-step error-feedback
// inner loops of both compress paths).
/// Block size of the L1-norm accumulation: f32 partial sums inside a
/// block (lane-parallel), f64 across blocks — no catastrophic
/// accumulation for n up to 10⁹.
const L1_BLK: usize = 4096;

#[inline(always)]
fn compensate_block(value: &[f32], err: &[f32], comp: &mut [f32]) -> f32 {
    // NOTE: the lane-accumulator order here and in
    // `compensate_block_in_place` must stay identical — the two entry
    // points below are required to return bit-identical scales (the
    // packed and two-pass compress paths are property-tested equal).
    let n = value.len();
    let split = n - n % LANES;
    let mut acc = [0.0f32; LANES];
    let mut i = 0;
    while i < split {
        for l in 0..LANES {
            let c = value[i + l] + err[i + l];
            comp[i + l] = c;
            acc[l] += c.abs();
        }
        i += LANES;
    }
    let mut part: f32 = acc.iter().sum();
    for k in split..n {
        let c = value[k] + err[k];
        comp[k] = c;
        part += c.abs();
    }
    part
}

#[inline(always)]
fn compensate_block_in_place(value: &[f32], err: &mut [f32]) -> f32 {
    let n = value.len();
    let split = n - n % LANES;
    let mut acc = [0.0f32; LANES];
    let mut i = 0;
    while i < split {
        for l in 0..LANES {
            let c = value[i + l] + err[i + l];
            err[i + l] = c;
            acc[l] += c.abs();
        }
        i += LANES;
    }
    let mut part: f32 = acc.iter().sum();
    for k in split..n {
        let c = value[k] + err[k];
        err[k] = c;
        part += c.abs();
    }
    part
}

/// Pass 1 of the EC 1-bit compress: write the compensated tensor
/// `value + err` into `comp` and return the quantizer scale
/// `‖value + err‖₁ / n`.  Lane-parallel partial sums inside
/// [`L1_BLK`]-element blocks (breaking the serial f32 dependency chain),
/// f64 across blocks.
pub fn compensate_l1(value: &[f32], err: &[f32], comp: &mut [f32]) -> f32 {
    let n = value.len();
    assert!(err.len() == n && comp.len() == n);
    if n == 0 {
        return 0.0;
    }
    let mut l1 = 0.0f64;
    let mut i = 0;
    while i < n {
        let end = (i + L1_BLK).min(n);
        l1 += compensate_block(&value[i..end], &err[i..end], &mut comp[i..end])
            as f64;
        i = end;
    }
    (l1 / n as f64) as f32
}

/// In-place variant of [`compensate_l1`]: `err` carries the error in and
/// the compensated tensor out.  Bit-identical scale (same block and lane
/// accumulation order).
pub fn compensate_l1_in_place(value: &[f32], err: &mut [f32]) -> f32 {
    let n = value.len();
    assert_eq!(err.len(), n);
    if n == 0 {
        return 0.0;
    }
    let mut l1 = 0.0f64;
    let mut i = 0;
    while i < n {
        let end = (i + L1_BLK).min(n);
        l1 += compensate_block_in_place(&value[i..end], &mut err[i..end])
            as f64;
        i = end;
    }
    (l1 / n as f64) as f32
}
// lint: end

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::backend::{MathBackend, ScalarBackend};
    use crate::util::check::{forall, ulp_diff};
    use crate::util::prng::Rng;

    /// ULP-bounded closeness with an absolute escape hatch for
    /// catastrophic-cancellation outputs near zero (where a 1-ULP input
    /// difference legitimately explodes in relative terms).
    fn close(a: f32, b: f32, max_ulp: u64) -> bool {
        ulp_diff(a, b) <= max_ulp || (a - b).abs() <= 1e-6
    }

    fn state(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let p = rng.normal_vec(n, 1.0);
        let m = rng.normal_vec(n, 0.1);
        let v: Vec<f32> =
            rng.normal_vec(n, 0.01).iter().map(|x| x.abs() + 1e-6).collect();
        let g = rng.normal_vec(n, 1.0);
        (p, m, v, g)
    }

    fn check_adam_vs_scalar(n: usize) -> Result<(), String> {
        let h = AdamHyper::default();
        let (p0, m0, v0, g) = state(n, n as u64 + 1);
        let (mut pf, mut mf, mut vf) = (p0.clone(), m0.clone(), v0.clone());
        adam_step_fused(h, &mut pf, &mut mf, &mut vf, &g, 1e-3);
        let (mut ps, mut ms, mut vs) = (p0, m0, v0);
        ScalarBackend
            .adam_step(h, &mut ps, &mut ms, &mut vs, &g, 1e-3)
            .unwrap();
        for i in 0..n {
            if !close(mf[i], ms[i], 4) {
                return Err(format!(
                    "m[{i}] {} vs {} (n={n})",
                    mf[i], ms[i]
                ));
            }
            if !close(vf[i], vs[i], 4) {
                return Err(format!(
                    "v[{i}] {} vs {} (n={n})",
                    vf[i], vs[i]
                ));
            }
            if !close(pf[i], ps[i], 8) {
                return Err(format!(
                    "p[{i}] {} vs {} (n={n})",
                    pf[i], ps[i]
                ));
            }
        }
        Ok(())
    }

    #[test]
    fn fused_adam_matches_scalar_within_ulps_property() {
        // Random lengths over the full 0..4096 range — non-multiple-of-
        // LANES tails included by construction.
        forall(60, |r| r.range(0, 4097), |&n: &usize| check_adam_vs_scalar(n));
    }

    #[test]
    fn fused_adam_every_tail_length() {
        // Exhaustive sweep of every tail residue around the lane width.
        for n in 0..=(3 * LANES + 1) {
            check_adam_vs_scalar(n).unwrap();
        }
        for n in [4095, 4096] {
            check_adam_vs_scalar(n).unwrap();
        }
    }

    #[test]
    fn fused_momentum_and_precond_match_scalar_property() {
        forall(
            60,
            |r| r.range(0, 4097),
            |&n: &usize| {
                let (p0, m0, v0, g) = state(n, n as u64 + 7);
                // momentum
                let mut mf = m0.clone();
                momentum_update_fused(0.9, &mut mf, &g);
                let mut ms = m0.clone();
                ScalarBackend.momentum_update(0.9, &mut ms, &g).unwrap();
                for i in 0..n {
                    if !close(mf[i], ms[i], 4) {
                        return Err(format!("momentum[{i}] n={n}"));
                    }
                }
                // precond
                let mut pf = p0.clone();
                precond_step_fused(1e-8, &mut pf, &m0, &v0, 1e-3);
                let mut ps = p0.clone();
                ScalarBackend
                    .precond_step(1e-8, &mut ps, &m0, &v0, 1e-3)
                    .unwrap();
                for i in 0..n {
                    if !close(pf[i], ps[i], 8) {
                        return Err(format!("precond[{i}] n={n}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn refresh_is_bitwise_copy_plus_update() {
        // The fused single-pass refresh must equal the two-pass
        // copy_from_slice + in-place update it replaces, bit for bit.
        forall(
            60,
            |r| r.range(0, 4097),
            |&n: &usize| {
                let mut rng = Rng::new(n as u64 + 13);
                let shared = rng.normal_vec(n, 0.5);
                let g = rng.normal_vec(n, 1.0);
                let mut fused = vec![0.0f32; n];
                momentum_refresh_fused(0.9, &shared, &g, &mut fused);
                let mut two_pass = shared.clone();
                momentum_update_fused(0.9, &mut two_pass, &g);
                if fused != two_pass {
                    return Err(format!("refresh diverged at n={n}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn par_variants_are_bit_identical_to_sequential() {
        let n = PAR_MIN_LEN + 137; // above the parallel threshold, odd tail
        let h = AdamHyper::default();
        let (p0, m0, v0, g) = state(n, 99);
        let (mut p1, mut m1, mut v1) = (p0.clone(), m0.clone(), v0.clone());
        adam_step_fused(h, &mut p1, &mut m1, &mut v1, &g, 1e-3);
        let (mut p2, mut m2, mut v2) = (p0.clone(), m0.clone(), v0.clone());
        adam_step_par(4, h, &mut p2, &mut m2, &mut v2, &g, 1e-3);
        assert_eq!(p1, p2);
        assert_eq!(m1, m2);
        assert_eq!(v1, v2);

        let mut q1 = p0.clone();
        precond_step_fused(1e-8, &mut q1, &m0, &v0, 1e-3);
        let mut q2 = p0.clone();
        precond_step_par(4, 1e-8, &mut q2, &m0, &v0, 1e-3);
        assert_eq!(q1, q2);
    }

    #[test]
    fn beta2_one_keeps_v_bitwise_frozen() {
        let h = AdamHyper { beta2: 1.0, ..AdamHyper::default() };
        let mut rng = Rng::new(3);
        let n = 100;
        let mut p = rng.normal_vec(n, 1.0);
        let mut m = rng.normal_vec(n, 0.1);
        let v0: Vec<f32> =
            rng.normal_vec(n, 1.0).iter().map(|x| x.abs() + 0.1).collect();
        let mut v = v0.clone();
        let g = rng.normal_vec(n, 1.0);
        adam_step_fused(h, &mut p, &mut m, &mut v, &g, 1e-2);
        assert_eq!(v, v0, "β₂=1 must freeze v exactly");
    }

    #[test]
    fn compensate_variants_bitwise_agree() {
        // The two pass-1 entry points (scratch-destination vs in-place)
        // must return the same scale and compensated values bit for bit,
        // across block and lane boundaries.
        for n in [0usize, 1, 7, 8, 9, 31, 4095, 4096, 4097, 10_000] {
            let mut rng = Rng::new(n as u64 + 21);
            let value = rng.normal_vec(n, 1.0);
            let err0 = rng.normal_vec(n, 0.3);
            let mut comp = vec![0.0f32; n];
            let s_a = compensate_l1(&value, &err0, &mut comp);
            let mut err = err0.clone();
            let s_b = compensate_l1_in_place(&value, &mut err);
            assert_eq!(s_a, s_b, "scale diverged at n={n}");
            assert_eq!(comp, err, "compensated tensor diverged at n={n}");
        }
    }
}
