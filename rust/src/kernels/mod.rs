//! Fused, vectorization-friendly CPU kernels — the crate's single home
//! for every elementwise hot loop.
//!
//! Layers above pick an engine, not a loop:
//!
//! * [`elementwise`] — fused Adam step, momentum refresh/update,
//!   preconditioned step, and the EC-compress L1/compensate pass, all on
//!   fixed [`elementwise::LANES`]-wide `chunks_exact` blocks with
//!   `f32::mul_add` chains, plus `*_par` fan-outs over
//!   [`crate::util::par`].
//! * [`reduce`] — the pairwise (tree) f64 summation behind the
//!   warmup-phase full-precision allreduce
//!   ([`crate::comm::plain::PlainPath::TreeReduce`]).
//!
//! Everything here is runtime-checked against a retained scalar
//! reference: the fused elementwise kernels against
//! [`crate::optim::backend::ScalarBackend`] (ULP-bounded property tests),
//! the tree reduction against
//! [`crate::comm::plain::PlainPath::Reference`] (≤ 1 ULP).

pub mod elementwise;
pub mod reduce;

pub use elementwise::{
    adam_step_fused, adam_step_par, compensate_l1, compensate_l1_in_place,
    momentum_refresh_fused, momentum_update_fused, precond_step_fused,
    precond_step_par, AdamHyper, LANES,
};
pub use reduce::{
    tree_average_into, tree_scaled_average_into, tree_sum_into, REDUCE_BLK,
};
