//! Low-overhead distributed tracing: per-thread ring-buffer span
//! recording, Chrome-trace export, a unified stats registry, and the
//! overlap/straggler/recovery analyzers.
//!
//! The paper's whole argument is a time-and-bytes accounting claim —
//! Table 1 reports that the backward allreduce is up to 94% of step
//! time, and §7.1 claims ~5× less wire volume under 1-bit compression.
//! Every span kind here maps onto a row of that accounting:
//!
//! | span kind          | Table 1 / paper stage                         |
//! |--------------------|-----------------------------------------------|
//! | `Compress`         | backward: EC 1-bit compress (Algorithm 1 l.7) |
//! | `PackVote`         | backward allreduce: sign-word vote-average    |
//! | `WireSend`         | backward allreduce: scatter/gather send       |
//! | `WireRecv`         | backward allreduce: blocking receive          |
//! | `ServerReduce`     | backward allreduce: server EC re-compress     |
//! | `Broadcast`        | backward allreduce: gather decode / intra-node|
//! |                    | broadcast (hierarchy stage 3)                 |
//! | `AdamKernel`       | "step": fused Adam / momentum / precond update|
//! | `VarianceResync`   | 0/1 Adam sync point (fp32 variance allreduce) |
//! | `CheckpointWrite`  | fault tolerance: atomic v2 checkpoint write   |
//! | `CheckpointRestore`| fault tolerance: reload + EC reshard          |
//! | `NackRetransmit`   | recovery layer: NACK sent / retransmit served |
//! | `RendezvousEpoch`  | elastic: join → WELCOME → mesh rebuild        |
//! | `PeerFailure`      | elastic: dead-peer budget exhausted (instant) |
//! | `ChaosFault`       | injected wire fault (instant)                 |
//! | `Step`             | one whole optimizer step (analysis anchor)    |
//! | `BucketCompute`    | overlap pipeline: produce bucket k (compute)  |
//! | `BucketComm`       | overlap pipeline: exchange bucket k (comm)    |
//! | `WireBytes`        | counter track: payload bytes this collective  |
//!
//! Recording is built to disappear when off: every instrumentation
//! point costs one relaxed atomic load and a branch
//! ([`is_enabled`]), bench-asserted < 1% of step time by
//! `benches/trace_overhead.rs`.  When on, each thread appends fixed-size
//! [`Event`]s to its own fixed-capacity overwrite-oldest ring — no
//! locks, and no heap allocation after the ring's one-time init (unit
//! tests assert both under the counting allocator).  Rings drain into a
//! global collector when their thread exits (scoped rank/comm threads)
//! or on [`take`], which merges everything into a [`sink::Trace`] for
//! Chrome-trace export ([`sink::Trace::to_chrome_string`]) and the
//! [`analysis`] reports.

pub mod analysis;
pub mod registry;
pub mod sink;

pub use registry::StatsRegistry;
pub use sink::Trace;

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock, PoisonError};
use std::time::Instant;

/// What a recorded stage *is* — see the module table for the mapping to
/// the paper's accounting rows.
#[repr(u8)]
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SpanKind {
    Compress = 0,
    PackVote,
    WireSend,
    WireRecv,
    ServerReduce,
    Broadcast,
    AdamKernel,
    VarianceResync,
    CheckpointWrite,
    CheckpointRestore,
    NackRetransmit,
    RendezvousEpoch,
    PeerFailure,
    ChaosFault,
    Step,
    BucketCompute,
    BucketComm,
    WireBytes,
}

impl SpanKind {
    pub const ALL: [SpanKind; 18] = [
        SpanKind::Compress,
        SpanKind::PackVote,
        SpanKind::WireSend,
        SpanKind::WireRecv,
        SpanKind::ServerReduce,
        SpanKind::Broadcast,
        SpanKind::AdamKernel,
        SpanKind::VarianceResync,
        SpanKind::CheckpointWrite,
        SpanKind::CheckpointRestore,
        SpanKind::NackRetransmit,
        SpanKind::RendezvousEpoch,
        SpanKind::PeerFailure,
        SpanKind::ChaosFault,
        SpanKind::Step,
        SpanKind::BucketCompute,
        SpanKind::BucketComm,
        SpanKind::WireBytes,
    ];

    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Compress => "Compress",
            SpanKind::PackVote => "PackVote",
            SpanKind::WireSend => "WireSend",
            SpanKind::WireRecv => "WireRecv",
            SpanKind::ServerReduce => "ServerReduce",
            SpanKind::Broadcast => "Broadcast",
            SpanKind::AdamKernel => "AdamKernel",
            SpanKind::VarianceResync => "VarianceResync",
            SpanKind::CheckpointWrite => "CheckpointWrite",
            SpanKind::CheckpointRestore => "CheckpointRestore",
            SpanKind::NackRetransmit => "NackRetransmit",
            SpanKind::RendezvousEpoch => "RendezvousEpoch",
            SpanKind::PeerFailure => "PeerFailure",
            SpanKind::ChaosFault => "ChaosFault",
            SpanKind::Step => "Step",
            SpanKind::BucketCompute => "BucketCompute",
            SpanKind::BucketComm => "BucketComm",
            SpanKind::WireBytes => "WireBytes",
        }
    }

    /// Chrome-trace category (Perfetto's track filter).
    pub fn category(self) -> &'static str {
        match self {
            SpanKind::Compress
            | SpanKind::PackVote
            | SpanKind::ServerReduce
            | SpanKind::Broadcast => "comm",
            SpanKind::WireSend | SpanKind::WireRecv => "wire",
            SpanKind::AdamKernel | SpanKind::VarianceResync => "optim",
            SpanKind::CheckpointWrite
            | SpanKind::CheckpointRestore
            | SpanKind::NackRetransmit
            | SpanKind::RendezvousEpoch
            | SpanKind::PeerFailure
            | SpanKind::ChaosFault => "recovery",
            SpanKind::Step
            | SpanKind::BucketCompute
            | SpanKind::BucketComm => "sched",
            SpanKind::WireBytes => "counter",
        }
    }

    pub fn from_u8(v: u8) -> Option<SpanKind> {
        Self::ALL.get(v as usize).copied()
    }

    pub fn parse(name: &str) -> Option<SpanKind> {
        Self::ALL.iter().copied().find(|k| k.name() == name)
    }
}

/// Duration span, point marker, or counter sample.
#[repr(u8)]
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum EventPhase {
    /// `[t0, t1]` duration span (Chrome `"X"`).
    Span = 0,
    /// Point-in-time marker at `t0` (Chrome `"i"`).
    Instant,
    /// Counter sample at `t0` with value `aux` (Chrome `"C"`).
    Counter,
}

impl EventPhase {
    pub fn from_u8(v: u8) -> Option<EventPhase> {
        match v {
            0 => Some(EventPhase::Span),
            1 => Some(EventPhase::Instant),
            2 => Some(EventPhase::Counter),
            _ => None,
        }
    }
}

/// The lane a thread records into — Chrome's `tid` within a rank's
/// process track.
pub const LANE_MAIN: u32 = 0;
/// The overlap pipeline's dedicated comm thread.
pub const LANE_COMM: u32 = 1;

/// Rank tag of threads that never called [`set_rank`] — the SPMD
/// driver / coordinator thread.
pub const DRIVER_RANK: u32 = u32::MAX;

/// One recorded event — fixed-size and `Copy`, so the hot-path ring
/// write is a plain store.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    pub kind: SpanKind,
    pub ph: EventPhase,
    /// Start (ns since the process trace epoch).
    pub t0_ns: u64,
    /// End; equals `t0_ns` for instants and counters.
    pub t1_ns: u64,
    /// Recording rank ([`DRIVER_RANK`] for untagged threads).
    pub rank: u32,
    /// Recording lane ([`LANE_MAIN`] / [`LANE_COMM`]).
    pub lane: u32,
    /// Kind-specific payload: bucket index, peer rank, byte count,
    /// epoch number, counter value.
    pub aux: u64,
}

impl Event {
    pub fn dur_ns(&self) -> u64 {
        self.t1_ns - self.t0_ns
    }
}

/// Default per-thread ring capacity (events).  At 56 B/event this is
/// ~3.5 MiB per recording thread.
pub const DEFAULT_CAPACITY: usize = 1 << 16;

// Atomic-ordering audit (all four statics):
//
// * Every load/store below is `Relaxed`, and that is sufficient — no
//   event data is ever published *through* these atomics.  Events live
//   in plain per-thread rings behind a `RefCell`; cross-thread handoff
//   happens exclusively under the `COLLECTED` mutex (drain-on-drop or
//   `flush_thread`), whose lock/unlock provides the acquire/release
//   edges for the payload.
// * `ENABLED` is an advisory gate: a span racing an enable/disable
//   edge may be missed or half-recorded-then-dropped, never torn —
//   there is no other memory whose visibility must be ordered with it.
// * `CAPACITY` is read once per thread at first-record to size the
//   ring; a racing `enable_with_capacity` can only make a brand-new
//   thread pick the old size, which is benign.
// * `DROPPED` is a monotonic statistics counter (`fetch_add`/load);
//   callers only read it after the producing threads have joined.
// * `EPOCH` is a `OnceLock`, which internally synchronizes its one
//   initialization; timestamps derived from it are plain data.
static ENABLED: AtomicBool = AtomicBool::new(false);
static CAPACITY: AtomicUsize = AtomicUsize::new(DEFAULT_CAPACITY);
static DROPPED: AtomicUsize = AtomicUsize::new(0);
static COLLECTED: Mutex<Vec<Event>> = Mutex::new(Vec::new());
static EPOCH: OnceLock<Instant> = OnceLock::new();

/// The single gate every instrumentation point checks.  Relaxed load:
/// recording is advisory — a span racing an `enable`/`disable` edge may
/// be missed, never torn.
#[inline(always)]
pub fn is_enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

fn now_ns() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// Start recording with the default per-thread ring capacity.
pub fn enable() {
    enable_with_capacity(DEFAULT_CAPACITY);
}

/// Start recording; each thread's ring holds `capacity` events and
/// overwrites its oldest beyond that.
pub fn enable_with_capacity(capacity: usize) {
    // Pin the epoch before the gate opens so every recorded timestamp
    // shares one time base.
    let _ = EPOCH.get_or_init(Instant::now);
    CAPACITY.store(capacity.max(16), Ordering::Relaxed);
    ENABLED.store(true, Ordering::Relaxed);
}

/// Stop recording.  Already-buffered events stay until [`take`] or
/// [`clear`].
pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
}

/// Tag the current thread's events with a rank (the Chrome `pid`).
pub fn set_rank(rank: usize) {
    LOCAL.with(|l| l.borrow_mut().ring.rank = rank as u32);
}

/// Tag the current thread's events with a lane (the Chrome `tid`).
pub fn set_lane(lane: u32) {
    LOCAL.with(|l| l.borrow_mut().ring.lane = lane);
}

/// The current thread's rank tag ([`DRIVER_RANK`] if never set) — lets
/// a helper thread (the overlap comm thread) adopt its spawner's rank.
pub fn current_rank() -> u32 {
    LOCAL.with(|l| l.borrow().ring.rank)
}

/// Events overwritten (ring overflow) across all threads so far.
pub fn dropped() -> usize {
    DROPPED.load(Ordering::Relaxed)
}

// ---- per-thread ring -------------------------------------------------------

struct Ring {
    buf: Vec<Event>,
    /// Next write slot once the ring is full.
    head: usize,
    dropped: usize,
    rank: u32,
    lane: u32,
}

impl Ring {
    const fn new() -> Ring {
        Ring {
            buf: Vec::new(),
            head: 0,
            dropped: 0,
            rank: DRIVER_RANK,
            lane: LANE_MAIN,
        }
    }

    // lint: hot-path — the armed ring write; the one-time
    // `reserve_exact` below is the only allocation a ring ever makes
    // (`push` past it never reallocates, overflow overwrites in place).
    #[inline]
    fn record(&mut self, mut ev: Event) {
        ev.rank = self.rank;
        ev.lane = self.lane;
        let cap = self.buf.capacity();
        if cap == 0 {
            // One-time init: the only allocation this ring ever makes.
            self.buf.reserve_exact(CAPACITY.load(Ordering::Relaxed));
        }
        if self.buf.len() < self.buf.capacity() {
            self.buf.push(ev);
        } else {
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.buf.len();
            self.dropped += 1;
        }
    }
    // lint: end

    /// Move the buffered events out in record order.
    fn drain(&mut self) -> Vec<Event> {
        let head = std::mem::take(&mut self.head);
        let mut out = std::mem::take(&mut self.buf);
        out.rotate_left(head);
        if self.dropped > 0 {
            DROPPED.fetch_add(self.dropped, Ordering::Relaxed);
            self.dropped = 0;
        }
        out
    }
}

/// Wrapper whose `Drop` hands the thread's ring to the global
/// collector — scoped rank/comm threads flush themselves on exit.
struct LocalRing {
    ring: Ring,
}

impl Drop for LocalRing {
    fn drop(&mut self) {
        let events = self.ring.drain();
        if !events.is_empty() {
            collected().extend(events);
        }
    }
}

thread_local! {
    static LOCAL: RefCell<LocalRing> =
        const { RefCell::new(LocalRing { ring: Ring::new() }) };
}

fn collected() -> std::sync::MutexGuard<'static, Vec<Event>> {
    COLLECTED.lock().unwrap_or_else(PoisonError::into_inner)
}

// lint: hot-path — armed recording entry points: everything from here
// to the collection section runs inside instrumented per-step code and
// is bench-asserted zero-alloc (`benches/trace_overhead.rs`).
#[inline]
fn record(ev: Event) {
    LOCAL.with(|l| l.borrow_mut().ring.record(ev));
}

// ---- recording API ---------------------------------------------------------

/// RAII duration span: records `[construction, drop]` when tracing is
/// enabled, does nothing (one atomic load) when it is not.
#[must_use = "a span records its duration when dropped"]
pub struct Span {
    kind: SpanKind,
    aux: u64,
    t0_ns: u64,
    armed: bool,
}

impl Span {
    /// Attach/overwrite the kind-specific payload before the span ends.
    #[inline]
    pub fn set_aux(&mut self, aux: u64) {
        self.aux = aux;
    }
}

impl Drop for Span {
    #[inline]
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        record(Event {
            kind: self.kind,
            ph: EventPhase::Span,
            t0_ns: self.t0_ns,
            t1_ns: now_ns(),
            rank: 0,
            lane: 0,
            aux: self.aux,
        });
    }
}

/// Open a duration span of `kind` (no payload).
#[inline]
pub fn span(kind: SpanKind) -> Span {
    span_aux(kind, 0)
}

/// Open a duration span of `kind` carrying `aux` (bucket index, peer
/// rank, byte count — see [`Event::aux`]).
#[inline]
pub fn span_aux(kind: SpanKind, aux: u64) -> Span {
    if !is_enabled() {
        return Span { kind, aux, t0_ns: 0, armed: false };
    }
    Span { kind, aux, t0_ns: now_ns(), armed: true }
}

/// Record a point-in-time marker.
#[inline]
pub fn instant(kind: SpanKind, aux: u64) {
    if !is_enabled() {
        return;
    }
    let t = now_ns();
    record(Event {
        kind,
        ph: EventPhase::Instant,
        t0_ns: t,
        t1_ns: t,
        rank: 0,
        lane: 0,
        aux,
    });
}

/// Record a counter sample (Chrome counter track, e.g. bytes on wire).
#[inline]
pub fn counter(kind: SpanKind, value: u64) {
    if !is_enabled() {
        return;
    }
    let t = now_ns();
    record(Event {
        kind,
        ph: EventPhase::Counter,
        t0_ns: t,
        t1_ns: t,
        rank: 0,
        lane: 0,
        aux: value,
    });
}
// lint: end

// ---- collection ------------------------------------------------------------

/// Drain the current thread's ring into the global collector without
/// waiting for thread exit.
pub fn flush_thread() {
    let events = LOCAL.with(|l| l.borrow_mut().ring.drain());
    if !events.is_empty() {
        collected().extend(events);
    }
}

/// Collect everything recorded so far (this thread + every thread that
/// has exited) into a [`Trace`], sorted by (rank, lane, start time).
/// Threads still alive elsewhere keep their un-drained rings — capture
/// after scoped work has joined.
pub fn take() -> Trace {
    flush_thread();
    let mut events = std::mem::take(&mut *collected());
    events.sort_by_key(|e| (e.rank, e.lane, e.t0_ns, e.t1_ns));
    Trace { events }
}

/// Drop everything recorded so far (current thread + collector) and
/// reset the overflow counter.
pub fn clear() {
    let _ = LOCAL.with(|l| l.borrow_mut().ring.drain());
    collected().clear();
    DROPPED.store(0, Ordering::Relaxed);
}

// Tests that *record* (enable the global gate, capture, assert on
// allocation counts) live in `tests/trace.rs`: the gate is
// process-global, and flipping it inside the lib test binary would race
// the comm/optim suites' own zero-allocation assertions running on
// sibling harness threads.  Only gate-free tests belong here.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_kind_tables_are_consistent() {
        for (i, k) in SpanKind::ALL.iter().enumerate() {
            assert_eq!(SpanKind::from_u8(i as u8), Some(*k));
            assert_eq!(SpanKind::parse(k.name()), Some(*k));
            assert!(!k.category().is_empty());
        }
        assert_eq!(SpanKind::from_u8(SpanKind::ALL.len() as u8), None);
        assert_eq!(SpanKind::parse("NotAKind"), None);
        for ph in [EventPhase::Span, EventPhase::Instant, EventPhase::Counter]
        {
            assert_eq!(EventPhase::from_u8(ph as u8), Some(ph));
        }
        assert_eq!(EventPhase::from_u8(3), None);
    }

    #[test]
    fn disabled_span_is_inert() {
        // Unit-scope sanity only (no gate flip): a span constructed
        // while disabled must not arm.
        if is_enabled() {
            return; // another process-level consumer owns the gate
        }
        let s = span_aux(SpanKind::Compress, 7);
        assert!(!s.armed);
        drop(s);
    }
}
