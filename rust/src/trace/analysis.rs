//! Trace analysis: turn a captured [`Trace`] into the three reports the
//! paper's accounting argument needs, each reconciled against its
//! analytic twin in `netsim`:
//!
//! * **overlap** — per-step comm-bubble fraction from the per-bucket
//!   `BucketCompute`/`BucketComm` spans, with the modeled step time
//!   recomputed through [`overlapped_step_time`]'s recurrence from the
//!   *measured* per-bucket durations;
//! * **straggler** — which peer's `WireRecv` gates the barrier;
//! * **recovery** — failure → rendezvous → restore timeline, checked
//!   against [`epoch_change_window_bound`]
//!   (crate::netsim::epoch_change_window_bound).
//!
//! [`overlapped_step_time`]: crate::netsim::collectives::overlapped_step_time

use std::collections::BTreeMap;
use std::time::Duration;

use super::sink::Trace;
use super::SpanKind;
use crate::metrics::Table;
use crate::netsim::collectives::overlapped_step_time;

// ---- overlap ---------------------------------------------------------------

/// One pipeline step's overlap accounting, reconstructed from per-bucket
/// spans.
#[derive(Debug, Clone, PartialEq)]
pub struct StepOverlap {
    /// Ordinal of the `Step` span this was carved from (per rank).
    pub step_index: usize,
    /// Rank the spans belong to.
    pub rank: u32,
    /// Per-bucket compute durations, bucket order.
    pub compute_ns: Vec<u64>,
    /// Per-bucket comm durations, bucket order.
    pub comm_ns: Vec<u64>,
    /// First bucket-compute start → last bucket-comm end.
    pub measured_ns: u64,
}

impl StepOverlap {
    /// Modeled step time: the [`overlapped_step_time`] recurrence
    /// evaluated on the **measured** per-bucket durations.  The live
    /// schedule can only be slower (channel hand-off, queue depth), so
    /// `measured_ns` is lower-bounded by this, up to clock jitter.
    pub fn modeled_ns(&self) -> f64 {
        let compute: Vec<f64> =
            self.compute_ns.iter().map(|&n| n as f64).collect();
        let comm: Vec<f64> = self.comm_ns.iter().map(|&n| n as f64).collect();
        overlapped_step_time(&compute, &comm)
    }

    /// Fully serialized schedule: Σ compute + Σ comm.
    pub fn serial_ns(&self) -> u64 {
        self.compute_ns.iter().sum::<u64>() + self.comm_ns.iter().sum::<u64>()
    }

    /// Time the step spent not computing (waiting on comm): the
    /// comm bubble.  Zero when comm hides entirely under compute.
    pub fn bubble_ns(&self) -> u64 {
        self.measured_ns
            .saturating_sub(self.compute_ns.iter().sum::<u64>())
    }

    /// Bubble as a fraction of the measured step, in `[0, 1]`.
    pub fn bubble_fraction(&self) -> f64 {
        if self.measured_ns == 0 {
            return 0.0;
        }
        self.bubble_ns() as f64 / self.measured_ns as f64
    }

    /// The bubble fraction the recurrence predicts from the same
    /// per-bucket durations — the reconciliation target for
    /// [`bubble_fraction`](Self::bubble_fraction).
    pub fn modeled_bubble_fraction(&self) -> f64 {
        let modeled = self.modeled_ns();
        if modeled <= 0.0 {
            return 0.0;
        }
        let compute: f64 = self.compute_ns.iter().sum::<u64>() as f64;
        ((modeled - compute) / modeled).max(0.0)
    }

    /// How much of the possible overlap the schedule realized:
    /// `(serial − measured) / (serial − modeled)`, clamped to `[0, 1]`;
    /// 1 when the modeled schedule leaves nothing to hide.
    pub fn overlap_efficiency(&self) -> f64 {
        let serial = self.serial_ns() as f64;
        let ideal_saving = serial - self.modeled_ns();
        if ideal_saving <= 0.0 {
            return 1.0;
        }
        let real_saving = serial - self.measured_ns as f64;
        (real_saving / ideal_saving).clamp(0.0, 1.0)
    }
}

/// Carve per-step overlap records for `rank` out of a trace.
///
/// Each `Step` span on the rank's main lane frames one step; the
/// `BucketCompute` spans inside it (main lane) and `BucketComm` spans
/// (any lane — the comm thread on the overlapped path, the main lane on
/// the sync path) are matched up by their bucket-index `aux`.  Steps
/// whose bucket sets don't line up (truncated ring) are skipped.
pub fn overlap_report(trace: &Trace, rank: u32) -> Vec<StepOverlap> {
    let steps: Vec<&super::Event> = trace
        .spans(SpanKind::Step)
        .filter(|e| e.rank == rank && e.lane == super::LANE_MAIN)
        .collect();
    let mut out = Vec::new();
    for (step_index, step) in steps.iter().enumerate() {
        let window = |e: &&super::Event| {
            e.rank == rank && e.t0_ns >= step.t0_ns && e.t1_ns <= step.t1_ns
        };
        let mut compute: BTreeMap<u64, u64> = BTreeMap::new();
        for e in trace.spans(SpanKind::BucketCompute).filter(window) {
            compute.insert(e.aux, e.dur_ns());
        }
        let mut comm: BTreeMap<u64, u64> = BTreeMap::new();
        for e in trace.spans(SpanKind::BucketComm).filter(window) {
            comm.insert(e.aux, e.dur_ns());
        }
        if compute.is_empty()
            || compute.len() != comm.len()
            || !compute.keys().eq(comm.keys())
        {
            continue;
        }
        let first_start = trace
            .spans(SpanKind::BucketCompute)
            .filter(window)
            .map(|e| e.t0_ns)
            .min()
            .unwrap();
        let last_end = trace
            .spans(SpanKind::BucketComm)
            .filter(window)
            .map(|e| e.t1_ns)
            .max()
            .unwrap();
        out.push(StepOverlap {
            step_index,
            rank,
            compute_ns: compute.into_values().collect(),
            comm_ns: comm.into_values().collect(),
            measured_ns: last_end.saturating_sub(first_start),
        });
    }
    out
}

/// Render a per-step overlap table (one row per step).
pub fn overlap_table(steps: &[StepOverlap]) -> Table {
    let mut t = Table::new(&[
        "step",
        "buckets",
        "measured ms",
        "modeled ms",
        "serial ms",
        "bubble %",
        "overlap eff",
    ]);
    for s in steps {
        t.row(&[
            s.step_index.to_string(),
            s.compute_ns.len().to_string(),
            format!("{:.3}", s.measured_ns as f64 / 1e6),
            format!("{:.3}", s.modeled_ns() / 1e6),
            format!("{:.3}", s.serial_ns() as f64 / 1e6),
            format!("{:.1}", 100.0 * s.bubble_fraction()),
            format!("{:.2}", s.overlap_efficiency()),
        ]);
    }
    t
}

// ---- straggler -------------------------------------------------------------

/// Which peer's `WireRecv` gates the barrier: per-peer receive-wait
/// totals aggregated across every rank's `WireRecv` spans (the span
/// `aux` carries the peer being waited on).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StragglerReport {
    /// Total nanoseconds every rank spent blocked receiving from each
    /// peer.
    pub wait_ns_by_peer: BTreeMap<u32, u64>,
}

impl StragglerReport {
    /// The peer the fleet waited on the longest, if any receives were
    /// traced.
    pub fn straggler(&self) -> Option<u32> {
        self.wait_ns_by_peer
            .iter()
            .max_by_key(|(peer, ns)| (**ns, u32::MAX - **peer))
            .map(|(peer, _)| *peer)
    }

    pub fn to_table(&self) -> Table {
        let mut t = Table::new(&["peer", "recv-wait ms", "gates barrier"]);
        let straggler = self.straggler();
        for (peer, ns) in &self.wait_ns_by_peer {
            t.row(&[
                peer.to_string(),
                format!("{:.3}", *ns as f64 / 1e6),
                if Some(*peer) == straggler { "*" } else { "" }.to_string(),
            ]);
        }
        t
    }
}

pub fn straggler_report(trace: &Trace) -> StragglerReport {
    let mut wait_ns_by_peer: BTreeMap<u32, u64> = BTreeMap::new();
    for e in trace.spans(SpanKind::WireRecv) {
        *wait_ns_by_peer.entry(e.aux as u32).or_insert(0) += e.dur_ns();
    }
    StragglerReport { wait_ns_by_peer }
}

// ---- recovery --------------------------------------------------------------

/// Failure → re-rendezvous → state-restore timeline for one surviving
/// rank, carved from `PeerFailure` / `RendezvousEpoch` /
/// `CheckpointRestore` events.
#[derive(Debug, Clone, PartialEq)]
pub struct RecoveryReport {
    pub rank: u32,
    /// When the rank observed the peer failure (trace clock, ns).
    pub failure_ns: u64,
    /// Re-rendezvous span (join + mesh dial) start/end.
    pub rendezvous_start_ns: u64,
    pub rendezvous_end_ns: u64,
    /// End of checkpoint restore (equals `rendezvous_end_ns` when the
    /// epoch restarted without reloading state).
    pub restore_end_ns: u64,
}

impl RecoveryReport {
    /// Failure detection → rendezvous begins.
    pub fn detection_ns(&self) -> u64 {
        self.rendezvous_start_ns.saturating_sub(self.failure_ns)
    }

    pub fn rendezvous_ns(&self) -> u64 {
        self.rendezvous_end_ns
            .saturating_sub(self.rendezvous_start_ns)
    }

    pub fn restore_ns(&self) -> u64 {
        self.restore_end_ns.saturating_sub(self.rendezvous_end_ns)
    }

    /// Full recovery window: failure observed → state restored.
    pub fn total_ns(&self) -> u64 {
        self.restore_end_ns.saturating_sub(self.failure_ns)
    }

    /// Check the measured window against the analytic
    /// [`epoch_change_window_bound`](crate::netsim::epoch_change_window_bound).
    pub fn within_bound(&self, bound: Duration) -> bool {
        Duration::from_nanos(self.total_ns()) <= bound
    }

    pub fn to_table(&self) -> Table {
        let mut t = Table::new(&["rank", "phase", "ms"]);
        for (phase, ns) in [
            ("detection", self.detection_ns()),
            ("rendezvous", self.rendezvous_ns()),
            ("restore", self.restore_ns()),
            ("total", self.total_ns()),
        ] {
            t.row(&[
                self.rank.to_string(),
                phase.to_string(),
                format!("{:.1}", ns as f64 / 1e6),
            ]);
        }
        t
    }
}

/// Recovery timeline for each rank that both observed a `PeerFailure`
/// and completed a subsequent `RendezvousEpoch`.
pub fn recovery_report(trace: &Trace) -> Vec<RecoveryReport> {
    let mut out = Vec::new();
    for rank in trace.ranks_with(SpanKind::PeerFailure) {
        let failure_ns = match trace
            .instants(SpanKind::PeerFailure)
            .filter(|e| e.rank == rank)
            .map(|e| e.t0_ns)
            .min()
        {
            Some(t) => t,
            None => continue,
        };
        let rendezvous = match trace
            .spans(SpanKind::RendezvousEpoch)
            .filter(|e| e.rank == rank && e.t0_ns >= failure_ns)
            .min_by_key(|e| e.t0_ns)
        {
            Some(e) => e,
            None => continue,
        };
        let restore_end_ns = trace
            .spans(SpanKind::CheckpointRestore)
            .filter(|e| e.rank == rank && e.t1_ns >= rendezvous.t1_ns)
            .map(|e| e.t1_ns)
            .min()
            .unwrap_or(rendezvous.t1_ns);
        out.push(RecoveryReport {
            rank,
            failure_ns,
            rendezvous_start_ns: rendezvous.t0_ns,
            rendezvous_end_ns: rendezvous.t1_ns,
            restore_end_ns,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Event, EventPhase, LANE_COMM, LANE_MAIN};

    fn span(
        kind: SpanKind,
        rank: u32,
        lane: u32,
        t0: u64,
        t1: u64,
        aux: u64,
    ) -> Event {
        Event {
            kind,
            ph: EventPhase::Span,
            t0_ns: t0,
            t1_ns: t1,
            rank,
            lane,
            aux,
        }
    }

    fn instant(kind: SpanKind, rank: u32, t0: u64, aux: u64) -> Event {
        Event {
            kind,
            ph: EventPhase::Instant,
            t0_ns: t0,
            t1_ns: t0,
            rank,
            lane: LANE_MAIN,
            aux,
        }
    }

    /// A hand-built 3-bucket pipeline step; the modeled time must equal
    /// the `overlapped_step_time` recurrence run on the same durations,
    /// exactly.
    #[test]
    fn overlap_report_matches_the_recurrence_exactly() {
        // compute: 100, 50, 50   comm: 80, 120, 40
        // recurrence: fc=100, comm ends 180; fc=150, comm ends 300;
        //             fc=200, comm ends 340.
        let events = vec![
            span(SpanKind::Step, 0, LANE_MAIN, 0, 400, 0),
            span(SpanKind::BucketCompute, 0, LANE_MAIN, 0, 100, 0),
            span(SpanKind::BucketCompute, 0, LANE_MAIN, 100, 150, 1),
            span(SpanKind::BucketCompute, 0, LANE_MAIN, 150, 200, 2),
            span(SpanKind::BucketComm, 0, LANE_COMM, 100, 180, 0),
            span(SpanKind::BucketComm, 0, LANE_COMM, 180, 300, 1),
            span(SpanKind::BucketComm, 0, LANE_COMM, 300, 340, 2),
        ];
        let trace = Trace { events };
        let steps = overlap_report(&trace, 0);
        assert_eq!(steps.len(), 1);
        let s = &steps[0];
        assert_eq!(s.compute_ns, vec![100, 50, 50]);
        assert_eq!(s.comm_ns, vec![80, 120, 40]);
        assert_eq!(s.measured_ns, 340);
        let modeled =
            overlapped_step_time(&[100.0, 50.0, 50.0], &[80.0, 120.0, 40.0]);
        assert_eq!(s.modeled_ns(), modeled);
        assert_eq!(modeled, 340.0);
        // bubble: 340 measured − 200 compute = 140.
        assert_eq!(s.bubble_ns(), 140);
        assert!((s.bubble_fraction() - 140.0 / 340.0).abs() < 1e-12);
        assert!(
            (s.bubble_fraction() - s.modeled_bubble_fraction()).abs() < 1e-12
        );
        // schedule achieved the recurrence exactly → efficiency 1.
        assert!((s.overlap_efficiency() - 1.0).abs() < 1e-12);
        assert_eq!(s.serial_ns(), 440);
        assert_eq!(overlap_table(&steps).render().lines().count(), 3);
    }

    #[test]
    fn overlap_report_skips_truncated_steps_and_single_bucket_is_serial() {
        let events = vec![
            // Step 0: bucket 1's comm span lost to ring overwrite.
            span(SpanKind::Step, 0, LANE_MAIN, 0, 300, 0),
            span(SpanKind::BucketCompute, 0, LANE_MAIN, 0, 100, 0),
            span(SpanKind::BucketCompute, 0, LANE_MAIN, 100, 200, 1),
            span(SpanKind::BucketComm, 0, LANE_COMM, 100, 200, 0),
            // Step 1: one bucket, sync path (comm on the main lane).
            span(SpanKind::Step, 0, LANE_MAIN, 300, 700, 0),
            span(SpanKind::BucketCompute, 0, LANE_MAIN, 300, 450, 0),
            span(SpanKind::BucketComm, 0, LANE_MAIN, 450, 650, 0),
        ];
        let trace = Trace { events };
        let steps = overlap_report(&trace, 0);
        assert_eq!(steps.len(), 1, "the truncated step must be skipped");
        let s = &steps[0];
        assert_eq!(s.step_index, 1);
        assert_eq!(s.measured_ns, 350);
        // single bucket → recurrence degenerates to the serial sum.
        assert_eq!(s.modeled_ns(), 350.0);
        assert_eq!(s.serial_ns(), 350);
        assert!((s.overlap_efficiency() - 1.0).abs() < 1e-12);
        assert!(overlap_report(&trace, 7).is_empty());
    }

    #[test]
    fn straggler_is_the_peer_with_the_largest_recv_wait() {
        let events = vec![
            span(SpanKind::WireRecv, 0, LANE_MAIN, 0, 50, 2),
            span(SpanKind::WireRecv, 1, LANE_MAIN, 0, 300, 2),
            span(SpanKind::WireRecv, 2, LANE_MAIN, 0, 40, 1),
            span(SpanKind::WireRecv, 0, LANE_MAIN, 60, 100, 1),
        ];
        let r = straggler_report(&Trace { events });
        assert_eq!(r.wait_ns_by_peer.get(&2), Some(&350));
        assert_eq!(r.wait_ns_by_peer.get(&1), Some(&80));
        assert_eq!(r.straggler(), Some(2));
        assert!(r.to_table().render().contains('*'));
        assert_eq!(straggler_report(&Trace::default()).straggler(), None);
    }

    #[test]
    fn recovery_report_breaks_down_the_window_and_checks_the_bound() {
        let ms = |v: u64| v * 1_000_000;
        let events = vec![
            // Rank 0's healthy first epoch, before the failure: must be
            // ignored when picking the post-failure rendezvous.
            span(SpanKind::RendezvousEpoch, 0, LANE_MAIN, 0, ms(10), 1),
            instant(SpanKind::PeerFailure, 0, ms(100), 2),
            span(
                SpanKind::RendezvousEpoch,
                0,
                LANE_MAIN,
                ms(150),
                ms(400),
                2,
            ),
            span(
                SpanKind::CheckpointRestore,
                0,
                LANE_MAIN,
                ms(400),
                ms(450),
                0,
            ),
            // Rank 1 saw the failure but never rejoined: no report.
            instant(SpanKind::PeerFailure, 1, ms(100), 2),
        ];
        let reports = recovery_report(&Trace { events });
        assert_eq!(reports.len(), 1);
        let r = &reports[0];
        assert_eq!(r.rank, 0);
        assert_eq!(r.detection_ns(), ms(50));
        assert_eq!(r.rendezvous_ns(), ms(250));
        assert_eq!(r.restore_ns(), ms(50));
        assert_eq!(r.total_ns(), ms(350));
        let bound = crate::netsim::epoch_change_window_bound(
            Duration::from_millis(200),
            Duration::from_millis(100),
            3,
        );
        // 200 + 100 + 3·250 = 1050 ms ≥ 350 ms.
        assert!(r.within_bound(bound));
        assert!(!r.within_bound(Duration::from_millis(349)));
        assert_eq!(r.to_table().render().lines().count(), 6);
    }
}
