//! Unified stats registry: one named-counter/gauge store subsuming the
//! scattered per-layer ledgers ([`CommStats`], [`TransportStats`],
//! [`RecoveryStats`]) with a single reconciliation point against the
//! `netsim` closed-form volume models.
//!
//! Every `record_*` ingester destructures its source struct
//! exhaustively (no `..`), so adding a field to any ledger is a compile
//! error here until the registry learns about it — the same
//! force-the-update pattern the ledger `merge` impls use.

use std::collections::BTreeMap;

use crate::comm::CommStats;
use crate::compress::CompressionKind;
use crate::netsim::collectives::compressed_step_payload_per_gpu;
use crate::transport::chaos::RecoveryStats;
use crate::transport::runner::TransportStats;
use crate::util::json::Json;

/// Named monotone counters (u64, additive on merge) plus gauges (f64,
/// last-write-wins).  Keys are `scope.metric` by convention.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StatsRegistry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
}

impl StatsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, name: &str, delta: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += delta;
    }

    pub fn set_gauge(&mut self, name: &str, value: f64) {
        self.gauges.insert(name.to_string(), value);
    }

    /// Counter value (0 when never touched).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn counters(&self) -> impl Iterator<Item = (&str, u64)> {
        self.counters.iter().map(|(k, v)| (k.as_str(), *v))
    }

    pub fn gauges(&self) -> impl Iterator<Item = (&str, f64)> {
        self.gauges.iter().map(|(k, v)| (k.as_str(), *v))
    }

    /// Fold another registry in: counters add, gauges last-write-wins.
    pub fn merge(&mut self, other: &StatsRegistry) {
        let StatsRegistry { counters, gauges } = other;
        for (k, v) in counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in gauges {
            self.gauges.insert(k.clone(), *v);
        }
    }

    // ---- ledger ingestion (exhaustive destructuring, no `..`) -------------

    /// Ingest one collective's payload ledger under `scope`.
    pub fn record_comm(&mut self, scope: &str, s: &CommStats) {
        let CommStats {
            alltoall_bytes_per_gpu,
            allgather_bytes_per_gpu,
            uncompressed_bytes,
        } = *s;
        self.add(
            &format!("{scope}.alltoall_bytes_per_gpu"),
            alltoall_bytes_per_gpu as u64,
        );
        self.add(
            &format!("{scope}.allgather_bytes_per_gpu"),
            allgather_bytes_per_gpu as u64,
        );
        self.add(
            &format!("{scope}.uncompressed_bytes"),
            uncompressed_bytes as u64,
        );
    }

    /// Ingest one transported step's measured wire ledger under `scope`.
    pub fn record_transport(&mut self, scope: &str, s: &TransportStats) {
        let TransportStats {
            comm,
            gross_alltoall_bytes,
            gross_allgather_bytes,
            gross_intra_bytes,
            frames_sent,
        } = *s;
        self.record_comm(scope, &comm);
        self.add(
            &format!("{scope}.gross_alltoall_bytes"),
            gross_alltoall_bytes as u64,
        );
        self.add(
            &format!("{scope}.gross_allgather_bytes"),
            gross_allgather_bytes as u64,
        );
        self.add(
            &format!("{scope}.gross_intra_bytes"),
            gross_intra_bytes as u64,
        );
        self.add(&format!("{scope}.frames_sent"), frames_sent as u64);
    }

    /// Ingest a chaos/recovery ledger under `scope`.
    pub fn record_recovery(&mut self, scope: &str, s: &RecoveryStats) {
        let RecoveryStats {
            frames_injected,
            injected_drops,
            injected_corruptions,
            injected_reorders,
            injected_delays,
            forced_clean,
            checksum_failures,
            gaps_detected,
            nacks_sent,
            retransmits_served,
            retransmit_bytes,
            duplicates_discarded,
            control_frames,
            control_bytes,
            nack_misses,
        } = *s;
        for (metric, v) in [
            ("frames_injected", frames_injected),
            ("injected_drops", injected_drops),
            ("injected_corruptions", injected_corruptions),
            ("injected_reorders", injected_reorders),
            ("injected_delays", injected_delays),
            ("forced_clean", forced_clean),
            ("checksum_failures", checksum_failures),
            ("gaps_detected", gaps_detected),
            ("nacks_sent", nacks_sent),
            ("retransmits_served", retransmits_served),
            ("retransmit_bytes", retransmit_bytes),
            ("duplicates_discarded", duplicates_discarded),
            ("control_frames", control_frames),
            ("control_bytes", control_bytes),
            ("nack_misses", nack_misses),
        ] {
            self.add(&format!("{scope}.{metric}"), v);
        }
    }

    // ---- reconciliation ----------------------------------------------------

    /// Measured per-GPU payload bytes recorded under `scope` (the
    /// `record_comm` convention).
    pub fn payload_per_gpu(&self, scope: &str) -> u64 {
        self.counter(&format!("{scope}.alltoall_bytes_per_gpu"))
            + self.counter(&format!("{scope}.allgather_bytes_per_gpu"))
    }

    /// The single reconciliation point against the netsim closed-form
    /// volume models: the measured per-GPU payload under `scope` must
    /// equal `expected_per_gpu` **exactly** (the models are byte-exact
    /// twins, not approximations).
    pub fn reconcile_payload(
        &self,
        scope: &str,
        expected_per_gpu: usize,
    ) -> std::result::Result<(), String> {
        let measured = self.payload_per_gpu(scope);
        if measured == expected_per_gpu as u64 {
            Ok(())
        } else {
            Err(format!(
                "{scope}: measured {measured} payload bytes/GPU, netsim \
                 closed form predicts {expected_per_gpu}"
            ))
        }
    }

    /// Reconcile a flat compressed-collective scope over `steps` steps
    /// against [`compressed_step_payload_per_gpu`]
    /// (crate::netsim::collectives).
    pub fn reconcile_compressed_steps(
        &self,
        scope: &str,
        kind: CompressionKind,
        n_gpus: usize,
        elements: usize,
        steps: usize,
    ) -> std::result::Result<(), String> {
        let per_step = compressed_step_payload_per_gpu(kind, n_gpus, elements);
        self.reconcile_payload(scope, steps * per_step)
    }

    // ---- rendering ---------------------------------------------------------

    pub fn to_table(&self) -> crate::metrics::Table {
        let mut t = crate::metrics::Table::new(&["metric", "value"]);
        for (k, v) in &self.counters {
            t.row(&[k.clone(), v.to_string()]);
        }
        for (k, v) in &self.gauges {
            t.row(&[k.clone(), format!("{v:.3}")]);
        }
        t
    }

    pub fn to_json(&self) -> Json {
        let mut counters = BTreeMap::new();
        for (k, v) in &self.counters {
            counters.insert(k.clone(), Json::Num(*v as f64));
        }
        let mut gauges = BTreeMap::new();
        for (k, v) in &self.gauges {
            gauges.insert(k.clone(), Json::Num(*v));
        }
        let mut root = BTreeMap::new();
        root.insert("counters".to_string(), Json::Obj(counters));
        root.insert("gauges".to_string(), Json::Obj(gauges));
        Json::Obj(root)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn counters_add_and_gauges_overwrite() {
        let mut r = StatsRegistry::new();
        r.add("x.bytes", 3);
        r.add("x.bytes", 4);
        r.set_gauge("x.frac", 0.5);
        r.set_gauge("x.frac", 0.75);
        assert_eq!(r.counter("x.bytes"), 7);
        assert_eq!(r.counter("never"), 0);
        assert_eq!(r.gauge("x.frac"), Some(0.75));
        assert_eq!(r.gauge("never"), None);

        let mut other = StatsRegistry::new();
        other.add("x.bytes", 1);
        other.set_gauge("x.frac", 0.25);
        r.merge(&other);
        assert_eq!(r.counter("x.bytes"), 8);
        assert_eq!(r.gauge("x.frac"), Some(0.25));
    }

    #[test]
    fn ingests_every_ledger_field() {
        let comm = CommStats {
            alltoall_bytes_per_gpu: 10,
            allgather_bytes_per_gpu: 20,
            uncompressed_bytes: 400,
        };
        let ts = TransportStats {
            comm,
            gross_alltoall_bytes: 111,
            gross_allgather_bytes: 222,
            gross_intra_bytes: 333,
            frames_sent: 12,
        };
        let rec = RecoveryStats {
            nacks_sent: 42,
            retransmit_bytes: 999,
            ..RecoveryStats::default()
        };
        let mut r = StatsRegistry::new();
        r.record_comm("car", &comm);
        r.record_transport("wire", &ts);
        r.record_recovery("chaos", &rec);
        assert_eq!(r.counter("car.alltoall_bytes_per_gpu"), 10);
        assert_eq!(r.counter("wire.allgather_bytes_per_gpu"), 20);
        assert_eq!(r.counter("wire.gross_intra_bytes"), 333);
        assert_eq!(r.counter("wire.frames_sent"), 12);
        assert_eq!(r.counter("chaos.nacks_sent"), 42);
        assert_eq!(r.counter("chaos.retransmit_bytes"), 999);
        assert_eq!(r.payload_per_gpu("wire"), 30);
        let table = r.to_table().render();
        assert!(table.contains("chaos.retransmit_bytes"));
        let j = r.to_json();
        assert_eq!(
            j.req("counters")
                .unwrap()
                .f64_of("car.uncompressed_bytes")
                .unwrap(),
            400.0
        );
    }

    #[test]
    fn reconciles_against_the_netsim_closed_form() {
        // Feed the registry the in-process engine's own per-step ledger
        // for a few steps; the closed form must agree byte-exactly.
        let (n, len, steps) = (4usize, 1031usize, 3usize);
        let mut car = crate::comm::CompressedAllreduce::new(
            n,
            len,
            CompressionKind::OneBit,
        );
        let base = Rng::new(5);
        let inputs: Vec<Vec<f32>> =
            (0..n).map(|i| base.fork(i as u64).normal_vec(len, 1.0)).collect();
        let mut out = vec![0.0f32; len];
        let mut reg = StatsRegistry::new();
        for _ in 0..steps {
            let s = car.allreduce(&inputs, &mut out);
            reg.record_comm("car", &s);
        }
        reg.reconcile_compressed_steps(
            "car",
            CompressionKind::OneBit,
            n,
            len,
            steps,
        )
        .expect("measured ledger must match the closed form");
        // And the failure path reports, not panics.
        assert!(reg
            .reconcile_compressed_steps(
                "car",
                CompressionKind::OneBit,
                n,
                len,
                steps + 1,
            )
            .is_err());
    }
}
