//! Trace sinks: merge collected events into Chrome-trace-format JSON
//! (loadable in `chrome://tracing` / Perfetto) or a compact fixed-width
//! binary dump (for tests and archival).
//!
//! Chrome mapping: one *process* track per rank (`pid` = rank, with the
//! untagged driver thread shown as its own process), one *thread* track
//! per lane (`tid`), `"X"` complete events for spans, `"i"` instants,
//! and `"C"` counter samples for the bytes-on-wire track.  Timestamps
//! are microseconds since the process trace epoch, as the format
//! requires.

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

use super::{Event, EventPhase, SpanKind, DRIVER_RANK, LANE_COMM, LANE_MAIN};
use crate::util::error::{Error, Result};
use crate::util::json::Json;

/// Chrome `pid` used for the untagged SPMD driver / coordinator thread
/// (`u32::MAX` itself would render as a meaningless huge number).
const DRIVER_PID: u64 = 1_000_000;

const BINARY_MAGIC: &[u8; 4] = b"OBTR";
const BINARY_VERSION: u32 = 1;
/// Bytes per event record in the binary dump.
const BINARY_RECORD: usize = 1 + 1 + 4 + 4 + 8 + 8 + 8;

/// A captured set of events (see [`super::take`]).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    pub events: Vec<Event>,
}

impl Trace {
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Duration spans of `kind`, in collection order.
    pub fn spans(&self, kind: SpanKind) -> impl Iterator<Item = &Event> {
        self.events
            .iter()
            .filter(move |e| e.kind == kind && e.ph == EventPhase::Span)
    }

    /// Instant markers of `kind`.
    pub fn instants(&self, kind: SpanKind) -> impl Iterator<Item = &Event> {
        self.events
            .iter()
            .filter(move |e| e.kind == kind && e.ph == EventPhase::Instant)
    }

    /// Every span kind with at least one event of any phase.
    pub fn kinds_present(&self) -> BTreeSet<SpanKind> {
        self.events.iter().map(|e| e.kind).collect()
    }

    /// Every rank that recorded at least one event (the driver's
    /// untagged rank included, as [`DRIVER_RANK`]).
    pub fn ranks(&self) -> BTreeSet<u32> {
        self.events.iter().map(|e| e.rank).collect()
    }

    /// Ranks that recorded at least one event of `kind`.
    pub fn ranks_with(&self, kind: SpanKind) -> BTreeSet<u32> {
        self.events
            .iter()
            .filter(|e| e.kind == kind)
            .map(|e| e.rank)
            .collect()
    }

    /// Total recorded duration of `kind` in nanoseconds.
    pub fn total_ns(&self, kind: SpanKind) -> u64 {
        self.spans(kind).map(Event::dur_ns).sum()
    }

    // ---- Chrome trace format ----------------------------------------------

    /// The trace as a Chrome-trace-format JSON value:
    /// `{"traceEvents": [...], "displayTimeUnit": "ms"}`.
    pub fn to_chrome_json(&self) -> Json {
        let mut events: Vec<Json> = Vec::with_capacity(self.events.len() + 8);
        // Metadata: name the per-rank process tracks and per-lane
        // threads so Perfetto shows "rank 3 / comm" instead of bare
        // numbers.
        let mut tracks: BTreeSet<(u64, u64)> = BTreeSet::new();
        for e in &self.events {
            tracks.insert((chrome_pid(e.rank), e.lane as u64));
        }
        for &(pid, tid) in &tracks {
            let pname = if pid == DRIVER_PID {
                "driver".to_string()
            } else {
                format!("rank {pid}")
            };
            events.push(metadata_event("process_name", pid, tid, &pname));
            let tname = match tid as u32 {
                LANE_MAIN => "main".to_string(),
                LANE_COMM => "comm".to_string(),
                other => format!("lane {other}"),
            };
            events.push(metadata_event("thread_name", pid, tid, &tname));
        }
        for e in &self.events {
            events.push(chrome_event(e));
        }
        let mut root = BTreeMap::new();
        root.insert("traceEvents".to_string(), Json::Arr(events));
        root.insert(
            "displayTimeUnit".to_string(),
            Json::Str("ms".to_string()),
        );
        Json::Obj(root)
    }

    pub fn to_chrome_string(&self) -> String {
        self.to_chrome_json().to_string_pretty() + "\n"
    }

    /// Write the Chrome-trace JSON to `path` (parent dirs created).
    pub fn write_chrome(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(path, self.to_chrome_string())
    }

    // ---- compact binary dump ----------------------------------------------

    /// Fixed-width little-endian dump: `"OBTR"`, version, count, then
    /// one 34-byte record per event.  Round-trips via
    /// [`Trace::from_binary`].
    pub fn to_binary(&self) -> Vec<u8> {
        let mut out =
            Vec::with_capacity(16 + self.events.len() * BINARY_RECORD);
        out.extend_from_slice(BINARY_MAGIC);
        out.extend_from_slice(&BINARY_VERSION.to_le_bytes());
        out.extend_from_slice(&(self.events.len() as u64).to_le_bytes());
        for e in &self.events {
            out.push(e.kind as u8);
            out.push(e.ph as u8);
            out.extend_from_slice(&e.rank.to_le_bytes());
            out.extend_from_slice(&e.lane.to_le_bytes());
            out.extend_from_slice(&e.t0_ns.to_le_bytes());
            out.extend_from_slice(&e.t1_ns.to_le_bytes());
            out.extend_from_slice(&e.aux.to_le_bytes());
        }
        out
    }

    pub fn from_binary(bytes: &[u8]) -> Result<Trace> {
        let bad = |what: &str| Error::Config(format!("trace dump: {what}"));
        if bytes.len() < 16 || &bytes[0..4] != BINARY_MAGIC {
            return Err(bad("missing OBTR header"));
        }
        let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
        if version != BINARY_VERSION {
            return Err(bad(&format!("unsupported version {version}")));
        }
        let count =
            u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
        let body = &bytes[16..];
        if body.len() != count * BINARY_RECORD {
            return Err(bad(&format!(
                "expected {} record bytes, found {}",
                count * BINARY_RECORD,
                body.len()
            )));
        }
        let mut events = Vec::with_capacity(count);
        for rec in body.chunks_exact(BINARY_RECORD) {
            let kind = SpanKind::from_u8(rec[0])
                .ok_or_else(|| bad(&format!("bad span kind {}", rec[0])))?;
            let ph = EventPhase::from_u8(rec[1])
                .ok_or_else(|| bad(&format!("bad phase {}", rec[1])))?;
            events.push(Event {
                kind,
                ph,
                rank: u32::from_le_bytes(rec[2..6].try_into().unwrap()),
                lane: u32::from_le_bytes(rec[6..10].try_into().unwrap()),
                t0_ns: u64::from_le_bytes(rec[10..18].try_into().unwrap()),
                t1_ns: u64::from_le_bytes(rec[18..26].try_into().unwrap()),
                aux: u64::from_le_bytes(rec[26..34].try_into().unwrap()),
            });
        }
        Ok(Trace { events })
    }

    /// Per-kind summary table: event count, total and mean span time,
    /// and the aux sum (bytes for the wire kinds).
    pub fn summary_table(&self) -> crate::metrics::Table {
        let mut t = crate::metrics::Table::new(&[
            "span kind", "events", "total ms", "mean µs", "aux sum",
        ]);
        for kind in SpanKind::ALL {
            let n = self
                .events
                .iter()
                .filter(|e| e.kind == kind)
                .count();
            if n == 0 {
                continue;
            }
            let total_ns = self.total_ns(kind);
            let spans = self.spans(kind).count();
            let mean_us = if spans > 0 {
                total_ns as f64 / spans as f64 / 1e3
            } else {
                0.0
            };
            let aux: u64 = self
                .events
                .iter()
                .filter(|e| e.kind == kind)
                .map(|e| e.aux)
                .sum();
            t.row(&[
                kind.name().to_string(),
                n.to_string(),
                format!("{:.3}", total_ns as f64 / 1e6),
                format!("{mean_us:.1}"),
                aux.to_string(),
            ]);
        }
        t
    }
}

fn chrome_pid(rank: u32) -> u64 {
    if rank == DRIVER_RANK {
        DRIVER_PID
    } else {
        rank as u64
    }
}

fn metadata_event(name: &str, pid: u64, tid: u64, value: &str) -> Json {
    let mut args = BTreeMap::new();
    args.insert("name".to_string(), Json::Str(value.to_string()));
    let mut m = BTreeMap::new();
    m.insert("name".to_string(), Json::Str(name.to_string()));
    m.insert("ph".to_string(), Json::Str("M".to_string()));
    m.insert("pid".to_string(), Json::Num(pid as f64));
    m.insert("tid".to_string(), Json::Num(tid as f64));
    m.insert("args".to_string(), Json::Obj(args));
    Json::Obj(m)
}

fn chrome_event(e: &Event) -> Json {
    let mut m = BTreeMap::new();
    m.insert("name".to_string(), Json::Str(e.kind.name().to_string()));
    m.insert("cat".to_string(), Json::Str(e.kind.category().to_string()));
    m.insert("pid".to_string(), Json::Num(chrome_pid(e.rank) as f64));
    m.insert("tid".to_string(), Json::Num(e.lane as f64));
    m.insert("ts".to_string(), Json::Num(e.t0_ns as f64 / 1e3));
    match e.ph {
        EventPhase::Span => {
            m.insert("ph".to_string(), Json::Str("X".to_string()));
            m.insert(
                "dur".to_string(),
                Json::Num(e.dur_ns() as f64 / 1e3),
            );
            let mut args = BTreeMap::new();
            args.insert("aux".to_string(), Json::Num(e.aux as f64));
            m.insert("args".to_string(), Json::Obj(args));
        }
        EventPhase::Instant => {
            m.insert("ph".to_string(), Json::Str("i".to_string()));
            m.insert("s".to_string(), Json::Str("t".to_string()));
            let mut args = BTreeMap::new();
            args.insert("aux".to_string(), Json::Num(e.aux as f64));
            m.insert("args".to_string(), Json::Obj(args));
        }
        EventPhase::Counter => {
            m.insert("ph".to_string(), Json::Str("C".to_string()));
            let mut args = BTreeMap::new();
            args.insert("bytes".to_string(), Json::Num(e.aux as f64));
            m.insert("args".to_string(), Json::Obj(args));
        }
    }
    Json::Obj(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(
        kind: SpanKind,
        ph: EventPhase,
        rank: u32,
        t0: u64,
        t1: u64,
        aux: u64,
    ) -> Event {
        Event { kind, ph, t0_ns: t0, t1_ns: t1, rank, lane: LANE_MAIN, aux }
    }

    fn sample() -> Trace {
        Trace {
            events: vec![
                ev(SpanKind::Compress, EventPhase::Span, 0, 1_000, 5_000, 3),
                ev(SpanKind::WireSend, EventPhase::Span, 1, 2_000, 4_000, 64),
                ev(
                    SpanKind::ChaosFault,
                    EventPhase::Instant,
                    1,
                    2_500,
                    2_500,
                    1,
                ),
                ev(
                    SpanKind::WireBytes,
                    EventPhase::Counter,
                    DRIVER_RANK,
                    6_000,
                    6_000,
                    4096,
                ),
            ],
        }
    }

    #[test]
    fn binary_roundtrip_is_exact() {
        let tr = sample();
        let bytes = tr.to_binary();
        let back = Trace::from_binary(&bytes).unwrap();
        assert_eq!(back, tr);
        // Truncation and corruption are detected, not misparsed.
        assert!(Trace::from_binary(&bytes[..bytes.len() - 1]).is_err());
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(Trace::from_binary(&bad).is_err());
        let mut bad_kind = bytes;
        bad_kind[16] = 250;
        assert!(Trace::from_binary(&bad_kind).is_err());
    }

    #[test]
    fn chrome_json_parses_and_maps_phases() {
        let tr = sample();
        let j = Json::parse(&tr.to_chrome_string()).unwrap();
        let evs = j.arr_of("traceEvents").unwrap();
        // 3 tracks × 2 metadata records + 4 events.
        assert_eq!(evs.len(), 10);
        let span = evs
            .iter()
            .find(|e| e.str_of("name") == Ok("Compress"))
            .unwrap();
        assert_eq!(span.str_of("ph").unwrap(), "X");
        assert_eq!(span.f64_of("ts").unwrap(), 1.0);
        assert_eq!(span.f64_of("dur").unwrap(), 4.0);
        assert_eq!(span.f64_of("pid").unwrap(), 0.0);
        let inst = evs
            .iter()
            .find(|e| e.str_of("name") == Ok("ChaosFault"))
            .unwrap();
        assert_eq!(inst.str_of("ph").unwrap(), "i");
        let ctr = evs
            .iter()
            .find(|e| e.str_of("name") == Ok("WireBytes"))
            .unwrap();
        assert_eq!(ctr.str_of("ph").unwrap(), "C");
        assert_eq!(
            ctr.req("args").unwrap().f64_of("bytes").unwrap(),
            4096.0
        );
        // The driver rank renders as its own named process.
        let meta = evs
            .iter()
            .find(|e| {
                e.str_of("ph") == Ok("M")
                    && e.str_of("name") == Ok("process_name")
                    && e.req("args").unwrap().str_of("name") == Ok("driver")
            })
            .expect("driver process metadata");
        assert_eq!(meta.f64_of("pid").unwrap(), DRIVER_PID as f64);
    }

    #[test]
    fn queries_cover_kinds_ranks_totals() {
        let tr = sample();
        assert_eq!(tr.len(), 4);
        assert!(tr.kinds_present().contains(&SpanKind::WireSend));
        assert_eq!(tr.ranks().len(), 3);
        assert_eq!(
            tr.ranks_with(SpanKind::WireSend),
            [1u32].into_iter().collect()
        );
        assert_eq!(tr.total_ns(SpanKind::Compress), 4_000);
        assert_eq!(tr.spans(SpanKind::Compress).count(), 1);
        assert_eq!(tr.instants(SpanKind::ChaosFault).count(), 1);
        let table = tr.summary_table().render();
        assert!(table.contains("Compress"));
        assert!(table.contains("WireBytes"));
    }

    #[test]
    fn empty_trace_renders_and_roundtrips() {
        let tr = Trace::default();
        assert!(tr.is_empty());
        let back = Trace::from_binary(&tr.to_binary()).unwrap();
        assert!(back.is_empty());
        let j = Json::parse(&tr.to_chrome_string()).unwrap();
        assert_eq!(j.arr_of("traceEvents").unwrap().len(), 0);
    }
}
