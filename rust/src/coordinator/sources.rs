//! Gradient sources: where each worker's `(loss, grad)` comes from.
//!
//! * [`LmSource`] / [`CnnSource`] — the real three-layer path: the AOT
//!   train-step artifact executed per worker via PJRT on that worker's
//!   microbatch.
//! * [`OracleSource`] — controlled synthetic oracles for the convergence
//!   sweeps and theory validation.

use std::rc::Rc;

use crate::data::{BlobImages, TokenCorpus};
use crate::optim::oracle::{QuadraticOracle, RippleOracle};
use crate::runtime::Runtime;
use crate::util::error::Result;
use crate::util::prng::Rng;

/// Produces worker `i`'s stochastic `(loss, gradient)` at given params.
pub trait GradSource {
    fn grad(&mut self, worker: usize, params: &[f32])
        -> Result<(f32, Vec<f32>)>;
    /// Parameter count this source's artifact expects.
    fn dim(&self) -> usize;
}

/// Causal-LM gradients from the `lm_train_step_<size>` artifact.
pub struct LmSource {
    rt: Rc<Runtime>,
    artifact: String,
    corpus: TokenCorpus,
    rngs: Vec<Rng>,
    batch: usize,
    seq: usize,
    dim: usize,
}

impl LmSource {
    pub fn new(rt: Rc<Runtime>, size: &str, n_workers: usize, seed: u64)
        -> Result<Self> {
        let artifact = format!("lm_train_step_{size}");
        let spec = rt.manifest().get(&artifact).ok_or_else(|| {
            crate::util::error::Error::msg(format!(
                "artifact '{artifact}' not found — re-run `make artifacts` \
                 (or artifacts-100m for lm-100m)"
            ))
        })?;
        let batch = spec.meta_usize("batch").unwrap_or(spec.inputs[1].shape[0]);
        let seq = spec.meta_usize("seq").unwrap_or(spec.inputs[1].shape[1]);
        let vocab = spec.meta_usize("vocab").unwrap_or(256);
        let dim = spec.inputs[0].elements();
        let corpus = TokenCorpus::new(vocab, 0.85);
        let rngs =
            (0..n_workers).map(|w| corpus.worker_rng(seed, w)).collect();
        Ok(LmSource { rt, artifact, corpus, rngs, batch, seq, dim })
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn seq(&self) -> usize {
        self.seq
    }
}

impl GradSource for LmSource {
    fn grad(
        &mut self,
        worker: usize,
        params: &[f32],
    ) -> Result<(f32, Vec<f32>)> {
        let (tokens, targets) = self.corpus.sample_batch(
            &mut self.rngs[worker],
            self.batch,
            self.seq,
        );
        self.rt.train_step(&self.artifact, params, &tokens, &targets)
    }

    fn dim(&self) -> usize {
        self.dim
    }
}

/// Classifier gradients from the `cnn_train_step` artifact.
pub struct CnnSource {
    rt: Rc<Runtime>,
    data: BlobImages,
    rngs: Vec<Rng>,
    batch: usize,
    dim: usize,
}

impl CnnSource {
    pub fn new(rt: Rc<Runtime>, n_workers: usize, noise: f32, seed: u64)
        -> Result<Self> {
        let spec = rt.manifest().get("cnn_train_step").ok_or_else(|| {
            crate::util::error::Error::msg(
                "artifact 'cnn_train_step' not found — run `make artifacts`",
            )
        })?;
        let batch = spec.meta_usize("batch").unwrap_or(spec.inputs[1].shape[0]);
        let in_dim = spec.meta_usize("in_dim").unwrap_or(256);
        let classes = spec.meta_usize("classes").unwrap_or(10);
        let dim = spec.inputs[0].elements();
        let data = BlobImages::new(in_dim, classes, noise, seed);
        let base = Rng::new(seed ^ 0xC1A55);
        let rngs = (0..n_workers).map(|w| base.fork(w as u64)).collect();
        Ok(CnnSource { rt, data, rngs, batch, dim })
    }

    /// Held-out accuracy via the `cnn_accuracy` artifact.
    pub fn test_accuracy(&self, params: &[f32], seed: u64) -> Result<f32> {
        let (x, y) = self.data.test_set(seed, self.batch);
        let (acc, _) = self.rt.cnn_step("cnn_accuracy", params, &x, &y)?;
        Ok(acc)
    }
}

impl GradSource for CnnSource {
    fn grad(
        &mut self,
        worker: usize,
        params: &[f32],
    ) -> Result<(f32, Vec<f32>)> {
        let (x, y) =
            self.data.sample_batch(&mut self.rngs[worker], self.batch);
        self.rt.cnn_step("cnn_train_step", params, &x, &y)
    }

    fn dim(&self) -> usize {
        self.dim
    }
}

/// Synthetic-oracle gradients (no PJRT — used by the big sweeps).
pub enum OracleSource {
    Quadratic { oracle: QuadraticOracle },
    Ripple { oracle: RippleOracle },
}

impl OracleSource {
    pub fn quadratic(oracle: QuadraticOracle, _init: Vec<f32>) -> Self {
        OracleSource::Quadratic { oracle }
    }

    pub fn ripple(oracle: RippleOracle) -> Self {
        OracleSource::Ripple { oracle }
    }
}

impl GradSource for OracleSource {
    fn grad(
        &mut self,
        worker: usize,
        params: &[f32],
    ) -> Result<(f32, Vec<f32>)> {
        match self {
            OracleSource::Quadratic { oracle } => {
                let g = oracle.grad(worker, params);
                Ok((oracle.value(params) as f32, g))
            }
            OracleSource::Ripple { oracle } => {
                let g = oracle.grad(worker, params);
                Ok((oracle.value(params) as f32, g))
            }
        }
    }

    fn dim(&self) -> usize {
        match self {
            OracleSource::Quadratic { oracle } => oracle.dim(),
            OracleSource::Ripple { oracle } => oracle.quad.dim(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_source_losses_are_consistent() {
        let oracle = QuadraticOracle::new(8, 2, 1.0, 1.0, 0.0, 0);
        let mut src = OracleSource::quadratic(oracle, vec![0.0; 8]);
        let x = vec![1.0f32; 8];
        let (loss, g) = src.grad(0, &x).unwrap();
        assert!((loss - 4.0).abs() < 1e-5); // 0.5 * 8 * 1
        assert_eq!(g.len(), 8);
        assert!((g[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn ripple_source_dims() {
        let oracle = RippleOracle::new(6, 3, 0.1, 0.5, 3.0, 1);
        let mut src = OracleSource::ripple(oracle);
        assert_eq!(src.dim(), 6);
        let (_, g) = src.grad(2, &vec![0.5; 6]).unwrap();
        assert_eq!(g.len(), 6);
    }
}
