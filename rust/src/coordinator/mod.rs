//! The training coordinator: data-parallel SPMD loop over a
//! [`DistOptimizer`], a [`GradSource`], the netsim clock, and the metrics
//! ledger.  This is the paper's "system" glued together.

pub mod checkpoint;
pub mod gan;
pub mod sources;

use std::time::Instant;

use crate::metrics::{RunLog, StepRecord};
use crate::netsim::collectives::{
    compressed_allreduce_time, fp16_allreduce_time,
};
use crate::netsim::{ComputeModel, NetworkModel};
use crate::optim::{DistOptimizer, Phase};
use crate::util::error::Result;

pub use sources::{CnnSource, GradSource, LmSource, OracleSource};

/// Learning-rate schedules used across the experiments.
#[derive(Debug, Clone, Copy)]
pub enum LrSchedule {
    Constant(f32),
    /// The paper's BERT schedule: linear ramp to `peak` over `warmup`
    /// steps, then ×`decay` every `every` steps (paper: 0.99 / 520).
    LinearWarmupExpDecay {
        peak: f32,
        warmup: usize,
        every: usize,
        decay: f32,
    },
    /// Figure 6's schedule: `base` ×`factor` every `every` steps.
    StepDecay { base: f32, every: usize, factor: f32 },
}

impl LrSchedule {
    pub fn lr(&self, step: usize) -> f32 {
        match *self {
            LrSchedule::Constant(lr) => lr,
            LrSchedule::LinearWarmupExpDecay { peak, warmup, every, decay } => {
                if step < warmup {
                    peak * (step + 1) as f32 / warmup as f32
                } else {
                    let k = (step - warmup) / every.max(1);
                    peak * decay.powi(k as i32)
                }
            }
            LrSchedule::StepDecay { base, every, factor } => {
                base * factor.powi((step / every.max(1)) as i32)
            }
        }
    }
}

/// Maps a step's phase + wire volume to simulated wall-clock via the
/// α–β network model and a GPU compute preset.
#[derive(Debug, Clone)]
pub struct TimingModel {
    pub net: NetworkModel,
    pub compute: ComputeModel,
    pub n_gpus: usize,
    pub grad_accum: usize,
    /// Charge communication as if the model had this many parameters
    /// (lets a scaled-down proxy model carry BERT-Large-sized traffic in
    /// the virtual clock).  `None` uses the optimizer's true dimension.
    pub params_override: Option<usize>,
}

impl TimingModel {
    /// Simulated seconds for one optimizer step over `dim` parameters.
    pub fn step_time(&self, phase: Phase, dim: usize) -> f64 {
        let dim = self.params_override.unwrap_or(dim);
        let compute = self.compute.step_compute(self.grad_accum);
        let comm = match phase {
            Phase::Warmup => fp16_allreduce_time(&self.net, self.n_gpus, dim),
            Phase::Compression => {
                compressed_allreduce_time(&self.net, self.n_gpus, dim)
            }
        };
        compute + comm
    }
}

/// Options for [`train`].
pub struct TrainOptions {
    pub steps: usize,
    pub schedule: LrSchedule,
    /// `None` disables the virtual clock (sim_time stays 0).
    pub timing: Option<TimingModel>,
    /// Print a progress line every `log_every` steps (0 = silent).
    pub log_every: usize,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            steps: 100,
            schedule: LrSchedule::Constant(1e-3),
            timing: None,
            log_every: 0,
        }
    }
}

/// Run the data-parallel training loop; returns the metric log.
pub fn train(
    opt: &mut dyn DistOptimizer,
    source: &mut dyn GradSource,
    opts: &TrainOptions,
) -> Result<RunLog> {
    let mut log = RunLog::new(opt.name());
    let mut sim_time = 0.0f64;
    let n = opt.n_workers();
    for step in 0..opts.steps {
        // lint: allow(timing): wall_time is reporting-only metadata on
        // StepRecord; the training state itself is simulated-clock only.
        let wall0 = Instant::now();
        let mut grads: Vec<Vec<f32>> = Vec::with_capacity(n);
        let mut loss_sum = 0.0f64;
        for w in 0..n {
            let (loss, g) = source.grad(w, opt.local_params(w))?;
            loss_sum += loss as f64;
            grads.push(g);
        }
        let lr = opts.schedule.lr(step);
        let stats = opt.step(&grads, lr);
        if let Some(tm) = &opts.timing {
            sim_time += tm.step_time(stats.phase, opt.dim());
        }
        let rec = StepRecord {
            step,
            loss: (loss_sum / n as f64) as f32,
            lr,
            phase: stats.phase,
            comm_bytes: stats.comm.total_per_gpu(),
            sim_time,
            wall_time: wall0.elapsed().as_secs_f64(),
        };
        if opts.log_every > 0 && step % opts.log_every == 0 {
            eprintln!(
                "[{}] step {:>6}  loss {:.4}  lr {:.2e}  phase {:?}  sim {:.1}s",
                log.name, step, rec.loss, lr, stats.phase, sim_time
            );
        }
        log.push(rec);
    }
    Ok(log)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::oracle::QuadraticOracle;
    use crate::optim::OptimizerKind;

    #[test]
    fn lr_schedule_paper_shape() {
        let s = LrSchedule::LinearWarmupExpDecay {
            peak: 4e-4,
            warmup: 100,
            every: 52,
            decay: 0.99,
        };
        assert!(s.lr(0) < s.lr(50));
        assert!((s.lr(99) - 4e-4).abs() < 1e-9);
        // decays after warmup
        assert!(s.lr(400) < 4e-4);
        // monotone non-increasing post warmup
        assert!(s.lr(300) >= s.lr(500));
    }

    #[test]
    fn step_decay_schedule() {
        let s = LrSchedule::StepDecay { base: 0.1, every: 100, factor: 0.1 };
        assert!((s.lr(0) - 0.1).abs() < 1e-9);
        assert!((s.lr(100) - 0.01).abs() < 1e-9);
        assert!((s.lr(250) - 0.001).abs() < 1e-9);
    }

    #[test]
    fn train_loop_descends_oracle() {
        let oracle = QuadraticOracle::new(32, 4, 0.5, 2.0, 0.05, 0);
        let mut src = OracleSource::quadratic(oracle, vec![1.0; 32]);
        let mut opt =
            OptimizerKind::Adam.build(4, vec![1.0; 32], None);
        let opts = TrainOptions {
            steps: 300,
            schedule: LrSchedule::Constant(0.05),
            timing: None,
            log_every: 0,
        };
        let log = train(opt.as_mut(), &mut src, &opts).unwrap();
        assert_eq!(log.records.len(), 300);
        assert!(log.final_loss().unwrap() < log.records[0].loss * 0.1);
    }

    #[test]
    fn every_record_carries_a_nonzero_wall_time() {
        // `train` is the single RunLog producer in the crate (the repro
        // figures and the CLI all route through it), so this pins the
        // wall_time ledger for every producer: each record must carry a
        // measured monotonic-clock duration, not the 0.0 default.
        let oracle = QuadraticOracle::new(16, 2, 0.5, 2.0, 0.05, 3);
        let mut src = OracleSource::quadratic(oracle, vec![1.0; 16]);
        let mut opt = OptimizerKind::OneBitAdam.build(2, vec![1.0; 16], Some(5));
        let opts = TrainOptions {
            steps: 25,
            schedule: LrSchedule::Constant(0.05),
            timing: None,
            log_every: 0,
        };
        let log = train(opt.as_mut(), &mut src, &opts).unwrap();
        assert_eq!(log.records.len(), 25);
        for r in &log.records {
            assert!(
                r.wall_time > 0.0,
                "step {} has wall_time {}",
                r.step,
                r.wall_time
            );
        }
    }

    #[test]
    fn timing_model_charges_more_for_warmup_phase() {
        let tm = TimingModel {
            net: NetworkModel::ethernet(),
            compute: ComputeModel::bert_large_v100(),
            n_gpus: 64,
            grad_accum: 1,
            params_override: None,
        };
        let dim = 340_000_000;
        let warm = tm.step_time(Phase::Warmup, dim);
        let comp = tm.step_time(Phase::Compression, dim);
        assert!(
            warm / comp > 3.0,
            "warmup {warm}s vs compression {comp}s"
        );
    }

    #[test]
    fn onebit_adam_end_to_end_with_timing() {
        let oracle = QuadraticOracle::new(64, 4, 0.5, 2.0, 0.05, 1);
        let mut src = OracleSource::quadratic(oracle, vec![1.0; 64]);
        let mut opt =
            OptimizerKind::OneBitAdam.build(4, vec![1.0; 64], Some(50));
        let opts = TrainOptions {
            steps: 400,
            schedule: LrSchedule::Constant(0.05),
            timing: Some(TimingModel {
                net: NetworkModel::ethernet(),
                compute: ComputeModel::bert_large_v100(),
                n_gpus: 4,
                grad_accum: 1,
                params_override: None,
            }),
            log_every: 0,
        };
        let log = train(opt.as_mut(), &mut src, &opts).unwrap();
        assert!(log.final_loss().unwrap() < 0.1);
        assert_eq!(log.warmup_steps(), 50);
        assert!(log.sim_time() > 0.0);
    }
}
