//! Checkpointing: save/restore training state to a compact binary format.
//!
//! The paper's two-stage structure makes checkpoints first-class: the
//! warmup can run once (expensive, full-precision) and the compression
//! stage can be relaunched from `v_{T_w}` repeatedly — exactly how the
//! DeepSpeed release is used in practice.
//!
//! Format (little-endian):
//! ```text
//! magic "OBAD" | version u32 | step u64 | phase u8 | dim u64
//! | params f32×dim | m f32×dim | v f32×dim
//! | crc32-like checksum u64 (fletcher)
//! ```

use std::io::{Read, Write};
use std::path::Path;

use crate::optim::Phase;
use crate::util::error::{Error, Result};

const MAGIC: &[u8; 4] = b"OBAD";
const VERSION: u32 = 1;

/// Serialized training state.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    pub phase: Phase,
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
}

fn fletcher64(data: &[u8]) -> u64 {
    let mut a: u64 = 0;
    let mut b: u64 = 0;
    for chunk in data.chunks(4) {
        let mut word = [0u8; 4];
        word[..chunk.len()].copy_from_slice(chunk);
        a = (a + u32::from_le_bytes(word) as u64) % 0xFFFF_FFFF;
        b = (b + a) % 0xFFFF_FFFF;
    }
    (b << 32) | a
}

fn push_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    buf.reserve(xs.len() * 4);
    for &x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn read_f32s(data: &[u8], off: &mut usize, n: usize) -> Result<Vec<f32>> {
    let need = n * 4;
    if *off + need > data.len() {
        return Err(Error::msg("checkpoint truncated"));
    }
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let s = *off + i * 4;
        out.push(f32::from_le_bytes([
            data[s],
            data[s + 1],
            data[s + 2],
            data[s + 3],
        ]));
    }
    *off += need;
    Ok(out)
}

impl Checkpoint {
    pub fn dim(&self) -> usize {
        self.params.len()
    }

    /// Serialize to bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let dim = self.params.len();
        assert_eq!(self.m.len(), dim);
        assert_eq!(self.v.len(), dim);
        let mut buf = Vec::with_capacity(21 + dim * 12 + 8);
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&self.step.to_le_bytes());
        buf.push(match self.phase {
            Phase::Warmup => 0,
            Phase::Compression => 1,
        });
        buf.extend_from_slice(&(dim as u64).to_le_bytes());
        push_f32s(&mut buf, &self.params);
        push_f32s(&mut buf, &self.m);
        push_f32s(&mut buf, &self.v);
        let sum = fletcher64(&buf);
        buf.extend_from_slice(&sum.to_le_bytes());
        buf
    }

    /// Parse from bytes (validates magic, version, length, checksum).
    pub fn from_bytes(data: &[u8]) -> Result<Checkpoint> {
        if data.len() < 29 {
            return Err(Error::msg("checkpoint too short"));
        }
        let (body, sum_bytes) = data.split_at(data.len() - 8);
        let stored = u64::from_le_bytes(sum_bytes.try_into().unwrap());
        if fletcher64(body) != stored {
            return Err(Error::msg("checkpoint checksum mismatch"));
        }
        if &body[..4] != MAGIC {
            return Err(Error::msg("bad checkpoint magic"));
        }
        let version = u32::from_le_bytes(body[4..8].try_into().unwrap());
        if version != VERSION {
            return Err(Error::msg(format!(
                "unsupported checkpoint version {version}"
            )));
        }
        let step = u64::from_le_bytes(body[8..16].try_into().unwrap());
        let phase = match body[16] {
            0 => Phase::Warmup,
            1 => Phase::Compression,
            p => return Err(Error::msg(format!("bad phase byte {p}"))),
        };
        let dim = u64::from_le_bytes(body[17..25].try_into().unwrap()) as usize;
        let mut off = 25usize;
        let params = read_f32s(body, &mut off, dim)?;
        let m = read_f32s(body, &mut off, dim)?;
        let v = read_f32s(body, &mut off, dim)?;
        if off != body.len() {
            return Err(Error::msg("checkpoint has trailing bytes"));
        }
        Ok(Checkpoint { step, phase, params, m, v })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let mut data = Vec::new();
        std::fs::File::open(path)?.read_to_end(&mut data)?;
        Checkpoint::from_bytes(&data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn sample(dim: usize) -> Checkpoint {
        let mut rng = Rng::new(1);
        Checkpoint {
            step: 12345,
            phase: Phase::Compression,
            params: rng.normal_vec(dim, 1.0),
            m: rng.normal_vec(dim, 0.1),
            v: rng.normal_vec(dim, 0.01).iter().map(|x| x.abs()).collect(),
        }
    }

    #[test]
    fn roundtrip_bytes() {
        let ck = sample(1000);
        let back = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert_eq!(ck, back);
    }

    #[test]
    fn roundtrip_file() {
        let dir = std::env::temp_dir().join("obadam_ck_test");
        let path = dir.join("test.ckpt");
        let ck = sample(257);
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn corruption_is_detected() {
        let ck = sample(64);
        let mut bytes = ck.to_bytes();
        // flip one payload bit
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        assert!(Checkpoint::from_bytes(&bytes).is_err());
    }

    #[test]
    fn truncation_is_detected() {
        let ck = sample(64);
        let bytes = ck.to_bytes();
        assert!(Checkpoint::from_bytes(&bytes[..bytes.len() - 9]).is_err());
        assert!(Checkpoint::from_bytes(&bytes[..10]).is_err());
    }

    #[test]
    fn warmup_phase_roundtrips() {
        let mut ck = sample(8);
        ck.phase = Phase::Warmup;
        let back = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert_eq!(back.phase, Phase::Warmup);
    }

    #[test]
    fn empty_dim_roundtrips() {
        let ck = Checkpoint {
            step: 0,
            phase: Phase::Warmup,
            params: vec![],
            m: vec![],
            v: vec![],
        };
        let back = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert_eq!(ck, back);
    }
}
