//! Checkpointing: save/restore training state to a compact binary format.
//!
//! The paper's two-stage structure makes checkpoints first-class: the
//! warmup can run once (expensive, full-precision) and the compression
//! stage can be relaunched from `v_{T_w}` repeatedly — exactly how the
//! DeepSpeed release is used in practice.
//!
//! Format v2 (little-endian):
//! ```text
//! magic "OBAD" | version u32 | step u64 | phase u8 | dim u64
//! | params f32×dim | m f32×dim | v f32×dim
//! | ec_count u32 | per buffer: len u64, f32×len
//! | crc32-like checksum u64 (fletcher, shared with the wire frames)
//! ```
//!
//! The `ec` buffers are the compression-stage error-feedback state in
//! [`crate::comm::Collective::export_errors`] order (worker/leader errors
//! then server-chunk errors — per *leader* under the hierarchical
//! topology), which makes a mid-compression save/restore resume the
//! Algorithm-1 trajectory **bit-identically** (tested below).  Version-1
//! files (no `ec` section) still load, with empty EC state.

use std::io::{Read, Write};
use std::path::Path;

use crate::optim::Phase;
use crate::util::error::Result;
use crate::util::hash::fletcher64;

const MAGIC: &[u8; 4] = b"OBAD";
const VERSION: u32 = 2;

/// Typed parse failure of a checkpoint file, naming the byte offset at
/// which the damage was found — the elastic restart path refuses a
/// truncated or bit-flipped file loudly instead of resuming from
/// garbage (and the atomic `save` below makes sure the last *good* file
/// is still on disk when it does).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CheckpointError {
    /// File ends before the field starting at `offset`: `need` more
    /// bytes were required, only `have` remain.
    Truncated { offset: usize, need: usize, have: usize },
    /// Fletcher64 trailer (at `offset`) disagrees with the body.
    ChecksumMismatch { offset: usize, stored: u64, computed: u64 },
    /// First four bytes are not `"OBAD"`.
    BadMagic { offset: usize },
    /// Unknown format version.
    BadVersion { offset: usize, version: u32 },
    /// Phase byte is neither warmup nor compression.
    BadPhase { offset: usize, byte: u8 },
    /// EC buffer count at `offset` implies more data than the file holds.
    EcCountOverflow { offset: usize, count: usize },
    /// EC buffer length at `offset` implies more data than the file holds.
    EcLenOverflow { offset: usize, len: usize },
    /// Parse consumed the body but `extra` bytes remain at `offset`.
    TrailingBytes { offset: usize, extra: usize },
}

impl std::fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CheckpointError::Truncated { offset, need, have } => write!(
                f,
                "checkpoint truncated at offset {offset}: need {need} \
                 more bytes, have {have}"
            ),
            CheckpointError::ChecksumMismatch {
                offset,
                stored,
                computed,
            } => write!(
                f,
                "checkpoint checksum mismatch at offset {offset}: \
                 stored {stored:#018x}, computed {computed:#018x}"
            ),
            CheckpointError::BadMagic { offset } => {
                write!(f, "bad checkpoint magic at offset {offset}")
            }
            CheckpointError::BadVersion { offset, version } => write!(
                f,
                "unsupported checkpoint version {version} at offset \
                 {offset}"
            ),
            CheckpointError::BadPhase { offset, byte } => write!(
                f,
                "bad checkpoint phase byte {byte} at offset {offset}"
            ),
            CheckpointError::EcCountOverflow { offset, count } => write!(
                f,
                "checkpoint ec count {count} at offset {offset} exceeds \
                 the file size"
            ),
            CheckpointError::EcLenOverflow { offset, len } => write!(
                f,
                "checkpoint ec buffer length {len} at offset {offset} \
                 exceeds the file size"
            ),
            CheckpointError::TrailingBytes { offset, extra } => write!(
                f,
                "checkpoint has {extra} trailing bytes at offset {offset}"
            ),
        }
    }
}

impl std::error::Error for CheckpointError {}

/// Serialized training state.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    pub phase: Phase,
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    /// Compression-stage error-feedback buffers
    /// ([`crate::comm::Collective::export_errors`] order); empty for
    /// warmup-phase checkpoints and files written by format v1.
    pub ec: Vec<Vec<f32>>,
}

fn push_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    buf.reserve(xs.len() * 4);
    for &x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn read_f32s(data: &[u8], off: &mut usize, n: usize) -> Result<Vec<f32>> {
    let need = n * 4;
    if *off + need > data.len() {
        return Err(CheckpointError::Truncated {
            offset: *off,
            need,
            have: data.len() - *off,
        }
        .into());
    }
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let s = *off + i * 4;
        out.push(f32::from_le_bytes([
            data[s],
            data[s + 1],
            data[s + 2],
            data[s + 3],
        ]));
    }
    *off += need;
    Ok(out)
}

impl Checkpoint {
    pub fn dim(&self) -> usize {
        self.params.len()
    }

    /// Serialize to bytes (format v2).
    pub fn to_bytes(&self) -> Vec<u8> {
        let dim = self.params.len();
        assert_eq!(self.m.len(), dim);
        assert_eq!(self.v.len(), dim);
        let ec_bytes: usize =
            self.ec.iter().map(|b| 8 + b.len() * 4).sum::<usize>() + 4;
        let mut buf = Vec::with_capacity(21 + dim * 12 + ec_bytes + 8);
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&self.step.to_le_bytes());
        buf.push(match self.phase {
            Phase::Warmup => 0,
            Phase::Compression => 1,
        });
        buf.extend_from_slice(&(dim as u64).to_le_bytes());
        push_f32s(&mut buf, &self.params);
        push_f32s(&mut buf, &self.m);
        push_f32s(&mut buf, &self.v);
        buf.extend_from_slice(&(self.ec.len() as u32).to_le_bytes());
        for b in &self.ec {
            buf.extend_from_slice(&(b.len() as u64).to_le_bytes());
            push_f32s(&mut buf, b);
        }
        let sum = fletcher64(&buf);
        buf.extend_from_slice(&sum.to_le_bytes());
        buf
    }

    /// Parse from bytes (validates magic, version, length, checksum).
    /// Accepts format v1 (no error-feedback section → `ec` empty) and v2.
    pub fn from_bytes(data: &[u8]) -> Result<Checkpoint> {
        if data.len() < 29 {
            return Err(CheckpointError::Truncated {
                offset: data.len(),
                need: 29 - data.len(),
                have: 0,
            }
            .into());
        }
        let (body, sum_bytes) = data.split_at(data.len() - 8);
        let stored = u64::from_le_bytes(sum_bytes.try_into().unwrap());
        let computed = fletcher64(body);
        if computed != stored {
            return Err(CheckpointError::ChecksumMismatch {
                offset: body.len(),
                stored,
                computed,
            }
            .into());
        }
        if &body[..4] != MAGIC {
            return Err(CheckpointError::BadMagic { offset: 0 }.into());
        }
        let version = u32::from_le_bytes(body[4..8].try_into().unwrap());
        if version != 1 && version != VERSION {
            return Err(
                CheckpointError::BadVersion { offset: 4, version }.into()
            );
        }
        let step = u64::from_le_bytes(body[8..16].try_into().unwrap());
        let phase = match body[16] {
            0 => Phase::Warmup,
            1 => Phase::Compression,
            byte => {
                return Err(
                    CheckpointError::BadPhase { offset: 16, byte }.into()
                )
            }
        };
        let dim = u64::from_le_bytes(body[17..25].try_into().unwrap()) as usize;
        let mut off = 25usize;
        let params = read_f32s(body, &mut off, dim)?;
        let m = read_f32s(body, &mut off, dim)?;
        let v = read_f32s(body, &mut off, dim)?;
        let mut ec = Vec::new();
        if version >= 2 {
            if off + 4 > body.len() {
                return Err(CheckpointError::Truncated {
                    offset: off,
                    need: 4,
                    have: body.len() - off,
                }
                .into());
            }
            let count = u32::from_le_bytes(
                body[off..off + 4].try_into().unwrap(),
            ) as usize;
            // Every buffer costs ≥ 8 header bytes — a count beyond that
            // is hostile/corrupt; reject before reserving anything.
            if count > (body.len() - off - 4) / 8 {
                return Err(CheckpointError::EcCountOverflow {
                    offset: off,
                    count,
                }
                .into());
            }
            off += 4;
            ec.reserve(count);
            for _ in 0..count {
                if off + 8 > body.len() {
                    return Err(CheckpointError::Truncated {
                        offset: off,
                        need: 8,
                        have: body.len() - off,
                    }
                    .into());
                }
                let blen = u64::from_le_bytes(
                    body[off..off + 8].try_into().unwrap(),
                ) as usize;
                // guard the multiply in read_f32s against a hostile length
                if blen > body.len() / 4 {
                    return Err(CheckpointError::EcLenOverflow {
                        offset: off,
                        len: blen,
                    }
                    .into());
                }
                off += 8;
                ec.push(read_f32s(body, &mut off, blen)?);
            }
        }
        if off != body.len() {
            return Err(CheckpointError::TrailingBytes {
                offset: off,
                extra: body.len() - off,
            }
            .into());
        }
        Ok(Checkpoint { step, phase, params, m, v, ec })
    }

    /// Atomic save: the bytes go to `<path>.tmp` first and are renamed
    /// into place only after a successful write + fsync, so a crash (or
    /// SIGKILL — the elastic runner's whole premise) mid-save can never
    /// destroy the last good checkpoint survivors will restore from.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let mut tmp = path.as_os_str().to_owned();
        tmp.push(".tmp");
        let tmp = std::path::PathBuf::from(tmp);
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&self.to_bytes())?;
        f.sync_all()?;
        drop(f);
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let mut data = Vec::new();
        std::fs::File::open(path)?.read_to_end(&mut data)?;
        Checkpoint::from_bytes(&data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn sample(dim: usize) -> Checkpoint {
        let mut rng = Rng::new(1);
        Checkpoint {
            step: 12345,
            phase: Phase::Compression,
            params: rng.normal_vec(dim, 1.0),
            m: rng.normal_vec(dim, 0.1),
            v: rng.normal_vec(dim, 0.01).iter().map(|x| x.abs()).collect(),
            ec: vec![
                rng.normal_vec(dim, 0.05),
                rng.normal_vec(dim / 2, 0.05),
            ],
        }
    }

    #[test]
    fn roundtrip_bytes() {
        let ck = sample(1000);
        let back = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert_eq!(ck, back);
    }

    #[test]
    fn roundtrip_file() {
        let dir = std::env::temp_dir().join("obadam_ck_test");
        let path = dir.join("test.ckpt");
        let ck = sample(257);
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn corruption_is_detected() {
        let ck = sample(64);
        let mut bytes = ck.to_bytes();
        // flip one payload bit
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        assert!(Checkpoint::from_bytes(&bytes).is_err());
    }

    #[test]
    fn truncation_is_detected() {
        let ck = sample(64);
        let bytes = ck.to_bytes();
        assert!(Checkpoint::from_bytes(&bytes[..bytes.len() - 9]).is_err());
        assert!(Checkpoint::from_bytes(&bytes[..10]).is_err());
    }

    #[test]
    fn parse_failures_are_typed_and_name_the_offset() {
        use crate::util::error::Error;
        let ck = sample(64);
        let bytes = ck.to_bytes();

        // Truncation inside the params section: the parser reports the
        // absolute body offset it needed to read at.
        match Checkpoint::from_bytes(&bytes[..10]) {
            Err(Error::Checkpoint(CheckpointError::Truncated {
                offset,
                ..
            })) => assert_eq!(offset, 10),
            other => panic!("want typed truncation, got {other:?}"),
        }

        // A flipped payload bit fails the fletcher check at the trailer.
        let mut bad = bytes.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x40;
        match Checkpoint::from_bytes(&bad) {
            Err(Error::Checkpoint(CheckpointError::ChecksumMismatch {
                offset,
                stored,
                computed,
            })) => {
                assert_eq!(offset, bytes.len() - 8);
                assert_ne!(stored, computed);
            }
            other => panic!("want checksum mismatch, got {other:?}"),
        }

        // Cutting the trailer short is a truncation, not a bad checksum.
        match Checkpoint::from_bytes(&bytes[..20]) {
            Err(Error::Checkpoint(CheckpointError::Truncated {
                offset,
                need,
                have,
            })) => {
                assert_eq!(offset, 20);
                assert_eq!(need, 9);
                assert_eq!(have, 0);
            }
            other => panic!("want header truncation, got {other:?}"),
        }
    }

    #[test]
    fn save_is_atomic_and_never_clobbers_the_last_good_file() {
        let dir = std::env::temp_dir().join("obadam_ck_atomic_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("latest.ckpt");
        let good = sample(91);
        good.save(&path).unwrap();
        // No staging residue after a successful save.
        assert!(!dir.join("latest.ckpt.tmp").exists());

        // Simulate a crash mid-save: a half-written staging file is
        // sitting next to the good checkpoint.  The good file still
        // loads — the partial write never touched it — and the next
        // save sweeps the residue away.
        let garbage = &good.to_bytes()[..40];
        std::fs::write(dir.join("latest.ckpt.tmp"), garbage).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap(), good);

        let mut newer = sample(91);
        newer.step += 1;
        newer.save(&path).unwrap();
        assert!(!dir.join("latest.ckpt.tmp").exists());
        assert_eq!(Checkpoint::load(&path).unwrap(), newer);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn warmup_phase_roundtrips() {
        let mut ck = sample(8);
        ck.phase = Phase::Warmup;
        let back = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert_eq!(back.phase, Phase::Warmup);
    }

    #[test]
    fn empty_dim_roundtrips() {
        let ck = Checkpoint {
            step: 0,
            phase: Phase::Warmup,
            params: vec![],
            m: vec![],
            v: vec![],
            ec: vec![],
        };
        let back = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert_eq!(ck, back);
    }

    #[test]
    fn version1_files_still_load_with_empty_ec() {
        // Hand-build a v1 file (no ec section) and parse it.
        let mut rng = Rng::new(4);
        let dim = 16usize;
        let params = rng.normal_vec(dim, 1.0);
        let m = rng.normal_vec(dim, 0.1);
        let v = rng.normal_vec(dim, 0.01);
        let mut buf = Vec::new();
        buf.extend_from_slice(b"OBAD");
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&77u64.to_le_bytes());
        buf.push(1u8); // compression phase
        buf.extend_from_slice(&(dim as u64).to_le_bytes());
        for xs in [&params, &m, &v] {
            for &x in xs.iter() {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        let sum = fletcher64(&buf);
        buf.extend_from_slice(&sum.to_le_bytes());
        let ck = Checkpoint::from_bytes(&buf).unwrap();
        assert_eq!(ck.step, 77);
        assert_eq!(ck.phase, Phase::Compression);
        assert_eq!(ck.params, params);
        assert!(ck.ec.is_empty());
    }

    #[test]
    fn ec_buffers_roundtrip_with_uneven_lengths() {
        let mut ck = sample(100);
        ck.ec = vec![vec![], vec![1.5, -2.5], vec![0.0; 33]];
        let back = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert_eq!(ck, back);
        // corrupting a byte inside the ec section is detected
        let mut bytes = ck.to_bytes();
        let pos = bytes.len() - 20; // inside the last ec buffer
        bytes[pos] ^= 0x04;
        assert!(Checkpoint::from_bytes(&bytes).is_err());
    }

    // ---- mid-compression resume (the transport-era contract) --------------

    use crate::comm::CommTopology;
    use crate::optim::onebit_adam::{OneBitAdam, OneBitAdamConfig};
    use crate::optim::DistOptimizer;

    fn run_steps(
        opt: &mut OneBitAdam,
        workers: usize,
        dim: usize,
        seed: u64,
        steps: usize,
    ) {
        let mut rng = Rng::new(seed);
        for _ in 0..steps {
            let grads: Vec<Vec<f32>> =
                (0..workers).map(|_| rng.normal_vec(dim, 1.0)).collect();
            opt.step(&grads, 1e-3);
        }
    }

    #[test]
    fn mid_compression_save_restore_resumes_bit_identically() {
        // Save mid-compression (error-feedback buffers hot, variance
        // frozen), restore through the *byte* format, and continue: the
        // restored run must track the original bit for bit — flat AND
        // hierarchical (per-leader error state).
        for topology in [
            CommTopology::Flat,
            CommTopology::Hierarchical { group_size: 2 },
        ] {
            let (workers, dim) = (4usize, 96usize);
            let cfg = OneBitAdamConfig {
                warmup_steps: Some(5),
                topology,
                ..Default::default()
            };
            let mut opt =
                OneBitAdam::new(workers, vec![0.4; dim], cfg.clone());
            run_steps(&mut opt, workers, dim, 11, 20); // 15 EC steps in
            let ck = opt.to_checkpoint();
            assert!(
                ck.ec.iter().any(|b| b.iter().any(|&e| e != 0.0)),
                "{topology:?}: mid-compression EC state should be hot"
            );
            // through the wire format, checksum and all
            let restored_ck =
                Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
            assert_eq!(ck, restored_ck);
            let mut resumed =
                OneBitAdam::from_checkpoint(workers, restored_ck, cfg);
            // the frozen variance came back exactly
            assert_eq!(opt.variance(), resumed.variance());
            // identical continuation
            run_steps(&mut opt, workers, dim, 99, 12);
            run_steps(&mut resumed, workers, dim, 99, 12);
            assert_eq!(
                opt.params(),
                resumed.params(),
                "{topology:?}: params diverged after resume"
            );
            assert_eq!(opt.momentum(), resumed.momentum());
            assert_eq!(
                opt.collective().export_errors(),
                resumed.collective().export_errors(),
                "{topology:?}: EC state diverged after resume"
            );
        }
    }

    #[test]
    fn hierarchical_checkpoint_carries_per_leader_error_state() {
        // Under the two-level topology the EC state is per *leader*: 2
        // nodes of 4 → 2 worker-error + 2 server-error buffers, not 8.
        let (workers, dim) = (8usize, 64usize);
        let cfg = OneBitAdamConfig {
            warmup_steps: Some(3),
            topology: CommTopology::Hierarchical { group_size: 4 },
            ..Default::default()
        };
        let mut opt = OneBitAdam::new(workers, vec![0.2; dim], cfg);
        run_steps(&mut opt, workers, dim, 5, 10);
        let ck = opt.to_checkpoint();
        assert_eq!(ck.ec.len(), 4, "2 leaders × (worker + server) buffers");
        assert_eq!(ck.ec[0].len(), dim);
        assert_eq!(ck.ec[1].len(), dim);
        assert_eq!(ck.ec[2].len() + ck.ec[3].len(), dim);
    }
}
