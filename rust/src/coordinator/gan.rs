//! Adversarial training driver (Figure 8): discriminator + generator, each
//! with its own distributed optimizer, trained in alternation over the
//! synthetic face-mode data.

use std::rc::Rc;

use crate::data::GanData;
use crate::optim::DistOptimizer;
use crate::runtime::Runtime;
use crate::util::error::{Error, Result};
use crate::util::prng::Rng;

/// One recorded GAN step.
#[derive(Debug, Clone, Copy)]
pub struct GanRecord {
    pub step: usize,
    pub d_loss: f32,
    pub g_loss: f32,
    pub comm_bytes: usize,
}

/// Alternating D/G training; both optimizers run the same data-parallel
/// collective machinery as the classifier experiments.
pub struct GanTrainer {
    rt: Rc<Runtime>,
    data: GanData,
    rngs: Vec<Rng>,
    batch: usize,
    z_dim: usize,
    data_dim: usize,
}

impl GanTrainer {
    pub fn new(rt: Rc<Runtime>, n_workers: usize, seed: u64) -> Result<Self> {
        let spec = rt
            .manifest()
            .get("gan_d_step")
            .ok_or_else(|| Error::msg("missing artifact 'gan_d_step'"))?;
        let batch = spec.meta_usize("batch").unwrap_or(64);
        let z_dim = spec.meta_usize("z_dim").unwrap_or(16);
        let data_dim = spec.meta_usize("data_dim").unwrap_or(64);
        let data = GanData::new(data_dim, 6, 0.05, seed);
        let base = Rng::new(seed ^ 0x6A42);
        let rngs = (0..n_workers).map(|w| base.fork(w as u64)).collect();
        Ok(GanTrainer { rt, data, rngs, batch, z_dim, data_dim })
    }

    pub fn data_dim(&self) -> usize {
        self.data_dim
    }

    /// One alternating step: D update then G update.
    pub fn step(
        &mut self,
        d_opt: &mut dyn DistOptimizer,
        g_opt: &mut dyn DistOptimizer,
        step: usize,
        d_lr: f32,
        g_lr: f32,
    ) -> Result<GanRecord> {
        let n = d_opt.n_workers();
        // ---- discriminator pass
        let mut d_grads = Vec::with_capacity(n);
        let mut d_loss = 0.0f64;
        for w in 0..n {
            let (real, z) = {
                let rng = &mut self.rngs[w];
                let real = self.data.sample_batch(rng, self.batch);
                let z = (0..self.batch * self.z_dim)
                    .map(|_| rng.normal() as f32)
                    .collect::<Vec<f32>>();
                (real, z)
            };
            let (loss, grad) = self.rt.gan_d_step(
                d_opt.local_params(w),
                g_opt.local_params(w),
                &real,
                &z,
            )?;
            d_loss += loss as f64;
            d_grads.push(grad);
        }
        let d_stats = d_opt.step(&d_grads, d_lr);

        // ---- generator pass
        let mut g_grads = Vec::with_capacity(n);
        let mut g_loss = 0.0f64;
        for w in 0..n {
            let z: Vec<f32> = {
                let rng = &mut self.rngs[w];
                (0..self.batch * self.z_dim)
                    .map(|_| rng.normal() as f32)
                    .collect()
            };
            let (loss, grad) = self.rt.gan_g_step(
                d_opt.local_params(w),
                g_opt.local_params(w),
                &z,
            )?;
            g_loss += loss as f64;
            g_grads.push(grad);
        }
        let g_stats = g_opt.step(&g_grads, g_lr);

        Ok(GanRecord {
            step,
            d_loss: (d_loss / n as f64) as f32,
            g_loss: (g_loss / n as f64) as f32,
            comm_bytes: d_stats.comm.total_per_gpu()
                + g_stats.comm.total_per_gpu(),
        })
    }
}
