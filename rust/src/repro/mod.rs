//! The paper-reproduction harness: one entry point per table/figure.
//!
//! `obadam repro <exp>` dispatches here; each experiment prints the same
//! rows/series the paper reports and writes CSV into `results/`.  See
//! DESIGN.md §4 for the experiment index and EXPERIMENTS.md for recorded
//! outcomes.

pub mod convergence;
pub mod timing;
pub mod theory;

use crate::util::error::{Error, Result};

/// All experiment ids, with a one-line description.
pub const EXPERIMENTS: &[(&str, &str)] = &[
    ("table1", "step-time breakdown / allreduce%% across cluster configs"),
    ("fig1", "naive EC-compressed Adam vs Adam (LM loss curves)"),
    ("fig2", "Adam variance-norm stabilization + auto-switch indicator"),
    ("fig4a", "sample-wise convergence: Adam vs 1-bit Adam (LM)"),
    ("fig4b", "time-wise convergence on the 64-GPU Ethernet cluster"),
    ("fig5a", "throughput scalability, batch = 16 x nGPU"),
    ("fig5b", "throughput scalability, total batch 4K"),
    ("fig5c", "SQuAD fine-tune throughput, batch = 3 x nGPU"),
    ("fig6", "CNN classifier: SGD/Adam/1-bit/32-bit/naive"),
    ("fig7", "ResNet-152-scale speedup on 10G/1G TCP"),
    ("fig8", "GAN: Adam vs 1-bit Adam loss trajectories"),
    ("fig9", "compression-stage speedup vs bandwidth (50 Mb - 3 Gb)"),
    ("fig10", "1-bit Adam vs DoubleSqueeze / Local SGD"),
    ("fig11", "1-bit Adam vs EF-momentum / local momentum"),
    ("fig12", "Adam with n-bit compressed variance (fails for low n)"),
    ("fig13", "Adam with lazily-updated variance (fails)"),
    ("table3", "fine-tune quality parity: compressed vs uncompressed"),
    ("volume", "end-to-end communication volume vs the paper's formula"),
    ("theory", "Corollary 1: linear speedup in n, epsilon sensitivity"),
];

/// Dispatch an experiment by id.  `fast` shrinks workloads ~4x for CI.
pub fn run(exp: &str, artifacts_dir: &str, out_dir: &str, fast: bool)
    -> Result<()> {
    match exp {
        "table1" => timing::table1(),
        "fig4b" => timing::fig4b(),
        "fig5a" => timing::fig5(timing::Fig5Variant::A),
        "fig5b" => timing::fig5(timing::Fig5Variant::B),
        "fig5c" => timing::fig5(timing::Fig5Variant::C),
        "fig7" => timing::fig7(),
        "fig9" => timing::fig9(),
        "volume" => timing::volume(),
        "fig1" => convergence::fig1(artifacts_dir, out_dir, fast),
        "fig2" => convergence::fig2(artifacts_dir, out_dir, fast),
        "fig4a" => convergence::fig4a(artifacts_dir, out_dir, fast),
        "fig6" => convergence::fig6(artifacts_dir, out_dir, fast),
        "fig8" => convergence::fig8(artifacts_dir, out_dir, fast),
        "fig10" => convergence::fig10(artifacts_dir, out_dir, fast),
        "fig11" => convergence::fig11(artifacts_dir, out_dir, fast),
        "fig12" => convergence::fig12(artifacts_dir, out_dir, fast),
        "fig13" => convergence::fig13(artifacts_dir, out_dir, fast),
        "table3" => convergence::table3(artifacts_dir, out_dir, fast),
        "theory" => theory::corollary1(out_dir, fast),
        "all" => {
            for (id, _) in EXPERIMENTS {
                println!("\n================ {id} ================");
                run(id, artifacts_dir, out_dir, fast)?;
            }
            Ok(())
        }
        other => Err(Error::Config(format!(
            "unknown experiment '{other}'; known: {}",
            EXPERIMENTS
                .iter()
                .map(|(k, _)| *k)
                .collect::<Vec<_>>()
                .join(", ")
        ))),
    }
}
