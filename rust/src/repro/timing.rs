//! Netsim-driven reproductions: Table 1 and Figures 4b/5/7/9 — the
//! experiments whose content is *timing shape*, reproduced analytically
//! over the calibrated α–β model (DESIGN.md §2).

use crate::metrics::Table;
use crate::netsim::collectives::{
    compressed_allreduce_time, fp16_allreduce_time,
    hierarchical_compressed_allreduce_time,
};
use crate::netsim::{ComputeModel, NetworkModel};
use crate::util::error::Result;

/// BERT-Large parameter count (the paper's headline workload).
pub const BERT_LARGE_PARAMS: usize = 340_000_000;
/// BERT-Base parameter count.
pub const BERT_BASE_PARAMS: usize = 110_000_000;
/// ResNet-152 parameter count (Figure 7 workload).
pub const RESNET152_PARAMS: usize = 60_000_000;

struct Table1Row {
    cluster: &'static str,
    nodes: usize,
    gpus: usize,
    batch_per_gpu: usize,
    accum: usize,
    /// Paper's measured backward-allreduce (ms) and allreduce%% columns.
    paper_allreduce_ms: f64,
    paper_pct: f64,
}

const TABLE1_ROWS: &[Table1Row] = &[
    Table1Row { cluster: "Ethernet", nodes: 16, gpus: 64, batch_per_gpu: 1, accum: 1, paper_allreduce_ms: 2205.86, paper_pct: 94.0 },
    Table1Row { cluster: "Ethernet", nodes: 16, gpus: 64, batch_per_gpu: 16, accum: 1, paper_allreduce_ms: 2275.43, paper_pct: 93.0 },
    Table1Row { cluster: "Ethernet", nodes: 16, gpus: 64, batch_per_gpu: 16, accum: 4, paper_allreduce_ms: 2259.36, paper_pct: 83.0 },
    Table1Row { cluster: "Ethernet", nodes: 8, gpus: 32, batch_per_gpu: 16, accum: 1, paper_allreduce_ms: 2173.35, paper_pct: 93.0 },
    Table1Row { cluster: "Ethernet", nodes: 4, gpus: 16, batch_per_gpu: 16, accum: 1, paper_allreduce_ms: 2133.24, paper_pct: 92.0 },
    Table1Row { cluster: "Ethernet", nodes: 2, gpus: 8, batch_per_gpu: 16, accum: 1, paper_allreduce_ms: 1897.21, paper_pct: 92.0 },
    Table1Row { cluster: "Ethernet", nodes: 1, gpus: 4, batch_per_gpu: 16, accum: 1, paper_allreduce_ms: 239.76, paper_pct: 58.0 },
    Table1Row { cluster: "InfiniBand", nodes: 8, gpus: 64, batch_per_gpu: 1, accum: 1, paper_allreduce_ms: 316.18, paper_pct: 75.0 },
    Table1Row { cluster: "InfiniBand", nodes: 8, gpus: 64, batch_per_gpu: 16, accum: 1, paper_allreduce_ms: 336.40, paper_pct: 69.0 },
    Table1Row { cluster: "InfiniBand", nodes: 8, gpus: 64, batch_per_gpu: 16, accum: 4, paper_allreduce_ms: 339.52, paper_pct: 44.0 },
    Table1Row { cluster: "InfiniBand", nodes: 4, gpus: 32, batch_per_gpu: 16, accum: 1, paper_allreduce_ms: 297.28, paper_pct: 67.0 },
    Table1Row { cluster: "InfiniBand", nodes: 2, gpus: 16, batch_per_gpu: 16, accum: 1, paper_allreduce_ms: 183.74, paper_pct: 55.0 },
    Table1Row { cluster: "InfiniBand", nodes: 1, gpus: 8, batch_per_gpu: 16, accum: 1, paper_allreduce_ms: 28.18, paper_pct: 16.0 },
];

/// Table 1: per-step latency breakdown + allreduce%%, model vs paper.
pub fn table1() -> Result<()> {
    let mut t = Table::new(&[
        "cluster", "nodes", "gpus", "b/gpu", "accum", "allreduce(ms)",
        "paper(ms)", "allreduce%", "paper%",
    ]);
    for row in TABLE1_ROWS {
        let net = if row.cluster == "Ethernet" {
            NetworkModel::ethernet()
        } else {
            NetworkModel::infiniband()
        };
        let compute = if row.batch_per_gpu == 1 {
            ComputeModel::bert_large_v100_b1()
        } else {
            ComputeModel::bert_large_v100()
        };
        let ar = fp16_allreduce_time(&net, row.gpus, BERT_LARGE_PARAMS);
        let total = compute.step_compute(row.accum) + ar;
        let pct = 100.0 * ar / total;
        t.row(&[
            row.cluster.to_string(),
            row.nodes.to_string(),
            row.gpus.to_string(),
            row.batch_per_gpu.to_string(),
            row.accum.to_string(),
            format!("{:.0}", ar * 1e3),
            format!("{:.0}", row.paper_allreduce_ms),
            format!("{pct:.0}"),
            format!("{:.0}", row.paper_pct),
        ]);
    }
    println!("Table 1 — BERT-Large seq128 step breakdown (model vs paper)");
    println!("{}", t.render());
    Ok(())
}

/// Samples/second for one step whose communication costs `comm` seconds
/// — the single home of the `step_compute + comm` throughput formula.
fn samples_per_sec(
    compute: &ComputeModel,
    gpus: usize,
    batch_per_gpu: usize,
    accum: usize,
    comm: f64,
) -> f64 {
    let step = compute.step_compute(accum) + comm;
    (gpus * batch_per_gpu * accum) as f64 / step
}

/// Samples/second for one Adam (warmup) or 1-bit (compression) step.
fn throughput(
    net: &NetworkModel,
    compute: &ComputeModel,
    gpus: usize,
    batch_per_gpu: usize,
    accum: usize,
    params: usize,
    compressed: bool,
) -> f64 {
    let comm = if compressed {
        compressed_allreduce_time(net, gpus, params)
    } else {
        fp16_allreduce_time(net, gpus, params)
    };
    samples_per_sec(compute, gpus, batch_per_gpu, accum, comm)
}

/// Samples/second for a 1-bit step over the hierarchical two-level
/// collective (one 1-bit leader per node, full-precision intra-node).
fn throughput_hier(
    net: &NetworkModel,
    compute: &ComputeModel,
    gpus: usize,
    batch_per_gpu: usize,
    accum: usize,
    params: usize,
) -> f64 {
    let comm = hierarchical_compressed_allreduce_time(net, gpus, params);
    samples_per_sec(compute, gpus, batch_per_gpu, accum, comm)
}

pub enum Fig5Variant {
    /// (a) pretraining, batch = 16 × nGPU
    A,
    /// (b) pretraining, total batch 4K (grad accumulation fills the gap)
    B,
    /// (c) SQuAD fine-tuning, batch = 3 × nGPU
    C,
}

/// Figure 5: compression-stage vs warmup-stage throughput scaling.
pub fn fig5(variant: Fig5Variant) -> Result<()> {
    let (title, batch_per_gpu, compute, total_batch): (_, usize, _, Option<usize>) =
        match variant {
            Fig5Variant::A => (
                "Fig 5(a) BERT-Large pretrain, batch=16/GPU",
                16,
                ComputeModel::bert_large_v100(),
                None,
            ),
            Fig5Variant::B => (
                "Fig 5(b) BERT-Large pretrain, total batch 4K",
                16,
                ComputeModel::bert_large_v100(),
                Some(4096),
            ),
            Fig5Variant::C => (
                "Fig 5(c) SQuAD fine-tune, batch=3/GPU",
                3,
                ComputeModel::bert_large_squad(),
                None,
            ),
        };
    let mut best_speedup: (f64, usize, &str) = (0.0, 0, "");
    for (net_name, net) in [
        ("Ethernet", NetworkModel::ethernet()),
        ("InfiniBand", NetworkModel::infiniband()),
    ] {
        let mut t = Table::new(&[
            "gpus", "adam (samples/s)", "1bit (samples/s)",
            "1bit-hier (samples/s)", "speedup", "hier speedup",
        ]);
        for gpus in [4usize, 8, 16, 32, 64, 128, 256] {
            let accum = match total_batch {
                Some(tb) => (tb / (batch_per_gpu * gpus)).max(1),
                None => 1,
            };
            let adam = throughput(
                &net, &compute, gpus, batch_per_gpu, accum,
                BERT_LARGE_PARAMS, false,
            );
            let onebit = throughput(
                &net, &compute, gpus, batch_per_gpu, accum,
                BERT_LARGE_PARAMS, true,
            );
            let hier = throughput_hier(
                &net, &compute, gpus, batch_per_gpu, accum,
                BERT_LARGE_PARAMS,
            );
            let sp = onebit / adam;
            if sp > best_speedup.0 {
                best_speedup = (sp, gpus, net_name);
            }
            t.row(&[
                gpus.to_string(),
                format!("{adam:.0}"),
                format!("{onebit:.0}"),
                format!("{hier:.0}"),
                format!("{sp:.2}x"),
                format!("{:.2}x", hier / adam),
            ]);
        }
        println!("{title} — {net_name}");
        println!("{}", t.render());
    }
    println!(
        "peak compression-stage speedup: {:.2}x at {} GPUs on {}",
        best_speedup.0, best_speedup.1, best_speedup.2
    );
    println!(
        "(1bit-hier: two-level collective, one 1-bit leader per node — \
         pays full-precision intra-node traffic, wins when the NIC tier \
         is the bottleneck)"
    );
    Ok(())
}

/// Figure 4(b): end-to-end time for the full BERT-Large seq128 schedule
/// (152K steps, 23K warmup) on 64 Ethernet GPUs — Adam vs 1-bit Adam.
pub fn fig4b() -> Result<()> {
    let net = NetworkModel::ethernet();
    let compute = ComputeModel::bert_large_v100();
    let gpus = 64;
    // total batch 4K at 16/GPU → accum 4
    let accum = 4096 / (16 * gpus);
    let total_steps = 152_000usize;
    let warmup = 23_000usize;

    let adam_step = compute.step_compute(accum)
        + fp16_allreduce_time(&net, gpus, BERT_LARGE_PARAMS);
    let onebit_step = compute.step_compute(accum)
        + compressed_allreduce_time(&net, gpus, BERT_LARGE_PARAMS);

    let adam_total = adam_step * total_steps as f64;
    let onebit_total = adam_step * warmup as f64
        + onebit_step * (total_steps - warmup) as f64;

    println!("Fig 4(b) — BERT-Large seq128 total training time, 64 GPUs, Ethernet");
    println!("  Adam      : {:>7.1} h   (paper: 174.3 h)", adam_total / 3600.0);
    println!("  1-bit Adam: {:>7.1} h   (paper:  51.5 h)", onebit_total / 3600.0);
    println!(
        "  end-to-end speedup: {:.2}x   (paper: 3.4x)",
        adam_total / onebit_total
    );
    Ok(())
}

/// Figure 7: ResNet-152-scale speedup on 10 Gb / 1 Gb TCP clusters.
pub fn fig7() -> Result<()> {
    let compute = ComputeModel::resnet152_v100();
    println!("Fig 7 — ResNet-152 (60M params) 1-bit Adam speedup over Adam");
    let mut t = Table::new(&["gpus", "10Gbit speedup", "1Gbit speedup"]);
    for gpus in [8usize, 16, 32, 64] {
        let mut row = vec![gpus.to_string()];
        for bw in [10.0, 1.0] {
            let net = NetworkModel::tcp(bw);
            let adam = compute.step_compute(1)
                + fp16_allreduce_time(&net, gpus, RESNET152_PARAMS);
            let onebit = compute.step_compute(1)
                + compressed_allreduce_time(&net, gpus, RESNET152_PARAMS);
            row.push(format!("{:.2}x", adam / onebit));
        }
        t.row(&row);
    }
    println!("{}", t.render());
    println!("(paper: speedup grows with GPUs; larger at 1 Gbit)");
    Ok(())
}

/// Figure 9: compression-stage speedup vs shaped bandwidth at 256 GPUs.
pub fn fig9() -> Result<()> {
    let compute = ComputeModel::bert_large_v100();
    let gpus = 256;
    println!(
        "Fig 9 — BERT-Large compression-stage speedup vs bandwidth (256 GPUs)"
    );
    let mut t = Table::new(&[
        "bandwidth", "adam step(s)", "1bit step(s)", "1bit-hier step(s)",
        "speedup", "hier speedup",
    ]);
    for mbit in [50.0, 100.0, 200.0, 500.0, 1000.0, 2000.0, 3000.0] {
        let net = NetworkModel::shaped_ethernet(mbit * 1e6);
        let adam = compute.step_compute(1)
            + fp16_allreduce_time(&net, gpus, BERT_LARGE_PARAMS);
        let onebit = compute.step_compute(1)
            + compressed_allreduce_time(&net, gpus, BERT_LARGE_PARAMS);
        let hier = compute.step_compute(1)
            + hierarchical_compressed_allreduce_time(
                &net,
                gpus,
                BERT_LARGE_PARAMS,
            );
        t.row(&[
            format!("{mbit:.0} Mbit"),
            format!("{adam:.1}"),
            format!("{onebit:.1}"),
            format!("{hier:.1}"),
            format!("{:.2}x", adam / onebit),
            format!("{:.2}x", adam / hier),
        ]);
    }
    println!("{}", t.render());
    println!("(paper: 10.83x @50Mbit, 6.59x @1Gbit, 5.93x @2Gbit)");
    println!(
        "(1bit-hier: leader-only inter-node exchange — the g× payload cut \
         pays off as bandwidth shrinks)"
    );
    Ok(())
}

/// §7.1 volume claim: end-to-end communication volume reduction
/// 1/(w + (1−w)/16) for the paper's Table 2 schedules, vs the byte ledger.
pub fn volume() -> Result<()> {
    use crate::comm::CompressedAllreduce;
    use crate::compress::CompressionKind;
    use crate::util::prng::Rng;

    println!("§7.1 — end-to-end communication volume reduction (vs fp16)");
    let mut t = Table::new(&[
        "schedule", "total", "warmup", "formula", "measured",
    ]);
    // measure actual per-step wire bytes with a small proxy tensor: the
    // ratio is size-independent.
    let dim = 100_000usize;
    let n = 4usize;
    let mut car = CompressedAllreduce::new(n, dim, CompressionKind::OneBit);
    let base = Rng::new(0);
    let inputs: Vec<Vec<f32>> =
        (0..n).map(|i| base.fork(i as u64).normal_vec(dim, 1.0)).collect();
    let mut out = vec![0.0f32; dim];
    let stats = car.allreduce(&inputs, &mut out);
    // fp16 ring baseline bytes per GPU for the same tensor
    let fp16_bytes = 2 * (dim * 2) * (n - 1) / n;
    let per_step_ratio = fp16_bytes as f64 / stats.total_per_gpu() as f64;

    for (name, total, warmup) in [
        ("BERT-Base seq128", 118_000usize, 16_000usize),
        ("BERT-Base seq512", 22_000, 1_500),
        ("BERT-Large seq128", 152_000, 23_000),
        ("BERT-Large seq512", 10_000, 1_500),
        ("SQuAD fine-tune", 1_848, 400),
    ] {
        let w = warmup as f64 / total as f64;
        let formula = 1.0 / (w + (1.0 - w) / 16.0);
        let measured = 1.0 / (w + (1.0 - w) / per_step_ratio);
        t.row(&[
            name.to_string(),
            total.to_string(),
            warmup.to_string(),
            format!("{formula:.2}x"),
            format!("{measured:.2}x"),
        ]);
    }
    println!("{}", t.render());
    println!(
        "measured per-step 1-bit wire ratio vs fp16: {per_step_ratio:.1}x \
         (paper assumes 16x)"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_timing_experiments_run() {
        table1().unwrap();
        fig4b().unwrap();
        fig5(Fig5Variant::A).unwrap();
        fig5(Fig5Variant::B).unwrap();
        fig5(Fig5Variant::C).unwrap();
        fig7().unwrap();
        fig9().unwrap();
        volume().unwrap();
    }

    #[test]
    fn fig4b_speedup_in_paper_band() {
        // shape check: 2.5x–4.5x end-to-end (paper: 3.4x)
        let net = NetworkModel::ethernet();
        let compute = ComputeModel::bert_large_v100();
        let accum = 4;
        let adam_step = compute.step_compute(accum)
            + fp16_allreduce_time(&net, 64, BERT_LARGE_PARAMS);
        let onebit_step = compute.step_compute(accum)
            + compressed_allreduce_time(&net, 64, BERT_LARGE_PARAMS);
        let total = 152_000f64;
        let warm = 23_000f64;
        let speedup = (adam_step * total)
            / (adam_step * warm + onebit_step * (total - warm));
        assert!(
            speedup > 2.5 && speedup < 4.5,
            "end-to-end speedup {speedup}"
        );
    }

    #[test]
    fn fig9_low_bandwidth_speedup_band() {
        // paper: 10.83x at 50 Mbit — accept 7x..17x
        let compute = ComputeModel::bert_large_v100();
        let net = NetworkModel::shaped_ethernet(50e6);
        let adam = compute.step_compute(1)
            + fp16_allreduce_time(&net, 256, BERT_LARGE_PARAMS);
        let onebit = compute.step_compute(1)
            + compressed_allreduce_time(&net, 256, BERT_LARGE_PARAMS);
        let sp = adam / onebit;
        assert!(sp > 7.0 && sp < 17.0, "speedup {sp}");
    }

    #[test]
    fn fig9_hierarchical_beats_flat_at_low_bandwidth() {
        // At 50 Mbit the NIC tier is the bottleneck, so the leader-only
        // exchange (g× smaller NIC payload) must beat the flat chunked
        // all-to-all end to end.
        let compute = ComputeModel::bert_large_v100();
        let net = NetworkModel::shaped_ethernet(50e6);
        let flat = compute.step_compute(1)
            + compressed_allreduce_time(&net, 256, BERT_LARGE_PARAMS);
        let hier = compute.step_compute(1)
            + hierarchical_compressed_allreduce_time(
                &net,
                256,
                BERT_LARGE_PARAMS,
            );
        assert!(hier < flat, "hier={hier} flat={flat}");
        let adam = compute.step_compute(1)
            + fp16_allreduce_time(&net, 256, BERT_LARGE_PARAMS);
        assert!(
            adam / hier > adam / flat,
            "hier speedup must exceed flat speedup at 50 Mbit"
        );
    }

    #[test]
    fn fig5a_peak_speedup_band() {
        // paper: 5.48x on Ethernet — accept 3.5x..8x at 64+ GPUs
        let compute = ComputeModel::bert_large_v100();
        let net = NetworkModel::ethernet();
        let adam =
            throughput(&net, &compute, 64, 16, 1, BERT_LARGE_PARAMS, false);
        let onebit =
            throughput(&net, &compute, 64, 16, 1, BERT_LARGE_PARAMS, true);
        let sp = onebit / adam;
        assert!(sp > 3.5 && sp < 8.0, "speedup {sp}");
    }

    #[test]
    fn fig5b_adam_peaks_then_flattens_while_onebit_scales() {
        // paper Fig 5(b): Adam throughput saturates with GPUs on Ethernet,
        // 1-bit keeps scaling.
        let compute = ComputeModel::bert_large_v100();
        let net = NetworkModel::ethernet();
        let tp = |gpus: usize, comp: bool| {
            let accum = (4096 / (16 * gpus)).max(1);
            throughput(&net, &compute, gpus, 16, accum, BERT_LARGE_PARAMS, comp)
        };
        // Adam: 32→128 GPUs gains < 1.6x (saturating)
        assert!(tp(128, false) / tp(32, false) < 1.6);
        // 1-bit: 32→128 GPUs gains > 2x (still scaling)
        assert!(tp(128, true) / tp(32, true) > 2.0);
    }

    #[test]
    fn table1_percentages_track_paper_shape() {
        // allreduce%% must be high on multi-node Ethernet, low on 1-node IB
        let eth = NetworkModel::ethernet();
        let c = ComputeModel::bert_large_v100();
        let ar = fp16_allreduce_time(&eth, 64, BERT_LARGE_PARAMS);
        let pct = 100.0 * ar / (c.step_compute(1) + ar);
        assert!(pct > 85.0, "ethernet 64 GPU pct {pct}");
        let ib = NetworkModel::infiniband();
        let ar1 = fp16_allreduce_time(&ib, 8, BERT_LARGE_PARAMS);
        let pct1 = 100.0 * ar1 / (c.step_compute(1) + ar1);
        assert!(pct1 < 35.0, "IB single-node pct {pct1}");
    }
}
