//! Theory validation (Theorem 1 / Corollary 1) on controlled oracles:
//!
//! 1. **Linear speedup in n** — in the σ-dominated regime the steps needed
//!    to reach a fixed gradient-norm level scale like 1/n.
//! 2. **ε sensitivity** — convergence degrades gracefully (not
//!    catastrophically) as compression error grows: 1-bit vs 4-bit vs
//!    uncompressed reach the same neighborhood, with the noise floor
//!    ordered by ε.

use crate::metrics::Table;
use crate::optim::onebit_adam::{OneBitAdam, OneBitAdamConfig};
use crate::optim::oracle::QuadraticOracle;
use crate::optim::{DistOptimizer};
use crate::compress::CompressionKind;
use crate::util::error::Result;
use crate::util::prng::Rng;

pub fn corollary1(out: &str, fast: bool) -> Result<()> {
    let dim = 128;
    let sigma = 1.0;
    let lr = 2e-3;

    // --- linear speedup in n ---------------------------------------------
    // Corollary 1's σ/√(nT) term governs the *noise-dominated* regime, so
    // we measure the steady-state loss floor at constant lr (the
    // bias-dominated descent phase is n-independent and would mask it).
    println!(
        "Corollary 1 — linear speedup: steady-state loss floor vs workers"
    );
    let steps = if fast { 2_000 } else { 6_000 };
    let mut t = Table::new(&["workers", "floor", "n x floor"]);
    let mut floors_n = Vec::new();
    for n in [1usize, 2, 4, 8, 16] {
        let mut oracle =
            QuadraticOracle::new(dim, n, 1.0, 1.0, sigma, 100);
        let init = Rng::new(0xF00D).normal_vec(dim, 1.0);
        let cfg = OneBitAdamConfig {
            warmup_steps: Some(40),
            ..Default::default()
        };
        let mut opt = OneBitAdam::new(n, init, cfg);
        let tail_n = steps / 4;
        let mut tail = 0.0;
        for t_ in 0..steps {
            let grads = oracle.grads(opt.params());
            opt.step(&grads, lr);
            if t_ >= steps - tail_n {
                tail += oracle.value(opt.params());
            }
        }
        let floor = tail / tail_n as f64;
        t.row(&[
            n.to_string(),
            format!("{floor:.5}"),
            format!("{:.5}", floor * n as f64),
        ]);
        floors_n.push((n, floor));
    }
    println!("{}", t.render());
    let (n0, f0) = floors_n[0];
    let (nk, fk) = floors_n[floors_n.len() - 1];
    println!(
        "floor ratio {:.1}x for {}x workers (linear speedup predicts \
         {:.0}x in the σ-dominated regime; the gap is the n-independent \
         ε²ᐟ³ compression term)",
        f0 / fk,
        nk / n0,
        nk as f64 / n0 as f64
    );

    // --- epsilon sensitivity ----------------------------------------------
    println!("\nCorollary 1 — compression-error sensitivity (noise floor)");
    let mut t2 = Table::new(&["compression", "final f (mean tail)"]);
    let mut floors = Vec::new();
    for (label, kind) in [
        ("none (fp32)", CompressionKind::None),
        ("8-bit", CompressionKind::NBit(8)),
        ("4-bit", CompressionKind::NBit(4)),
        ("1-bit", CompressionKind::OneBit),
    ] {
        let steps = if fast { 2_000 } else { 6_000 };
        let mut oracle = QuadraticOracle::new(dim, 8, 1.0, 1.0, 0.01, 7);
        let init = Rng::new(0xBEEF).normal_vec(dim, 1.0);
        let cfg = OneBitAdamConfig {
            warmup_steps: Some(40),
            compression: kind,
            ..Default::default()
        };
        let mut opt = OneBitAdam::new(8, init, cfg);
        let mut tail = 0.0f64;
        let tail_n = 500;
        for t in 0..steps {
            let grads = oracle.grads(opt.params());
            // constant lr: the steady-state floor is the ε readout
            opt.step(&grads, 2e-3);
            if t >= steps - tail_n {
                tail += oracle.value(opt.params());
            }
        }
        let floor = tail / tail_n as f64;
        t2.row(&[label.to_string(), format!("{floor:.5}")]);
        floors.push((label, floor));
    }
    println!("{}", t2.render());
    println!(
        "(floors ordered by ε, all finite — compression degrades gracefully \
         as the ε²ᐟ³/T²ᐟ³ term predicts)"
    );
    std::fs::create_dir_all(out)?;
    let csv: String = floors
        .iter()
        .map(|(l, f)| format!("{l},{f}\n"))
        .collect();
    std::fs::write(format!("{out}/theory_floors.csv"), csv)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Steady-state loss (noise floor) after `steps` at constant lr.
    fn noise_floor(n_workers: usize, steps: usize) -> f64 {
        let dim = 64;
        let mut oracle =
            QuadraticOracle::new(dim, n_workers, 1.0, 1.0, 1.0, 5);
        let init = Rng::new(0xACE).normal_vec(dim, 1.0);
        let cfg = OneBitAdamConfig {
            warmup_steps: Some(40),
            ..Default::default()
        };
        let mut opt = OneBitAdam::new(n_workers, init, cfg);
        let tail_n = steps / 4;
        let mut tail = 0.0;
        for t in 0..steps {
            let grads = oracle.grads(opt.params());
            opt.step(&grads, 2e-3);
            if t >= steps - tail_n {
                tail += oracle.value(opt.params());
            }
        }
        tail / tail_n as f64
    }

    #[test]
    fn linear_speedup_shows_in_noise_floor() {
        // Corollary 1's σ/√(nT) term: in the σ-dominated steady state the
        // loss floor scales ~1/n.  8x workers ⇒ ≥3x lower floor.
        let f1 = noise_floor(1, 4000);
        let f8 = noise_floor(8, 4000);
        assert!(
            f1 / f8 > 3.0,
            "expected ≥3x lower floor with 8x workers: f1={f1} f8={f8}"
        );
    }
}
