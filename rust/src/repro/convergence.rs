//! PJRT-driven convergence reproductions: Figures 1, 2, 4(a), 6, 8,
//! 10–13 and Table 3.  Gradients come from the AOT train-step artifacts
//! (the real three-layer path); optimizers/communication are byte-accurate.

use std::rc::Rc;

use crate::coordinator::{
    train, CnnSource, GradSource, LmSource, LrSchedule, TimingModel,
    TrainOptions,
};
use crate::metrics::{RunLog, Table};
use crate::netsim::{ComputeModel, NetworkModel};
use crate::optim::onebit_adam::{OneBitAdam, OneBitAdamConfig};
use crate::optim::variance_ablation::{LazyVarianceAdam, NBitVarianceAdam};
use crate::optim::{Adam, DistOptimizer, OptimizerKind};
use crate::runtime::Runtime;
use crate::util::error::Result;
use crate::util::prng::Rng;

fn runtime(dir: &str) -> Result<Rc<Runtime>> {
    Ok(Rc::new(Runtime::load(dir)?))
}

fn scale(fast: bool, n: usize) -> usize {
    if fast {
        (n / 4).max(20)
    } else {
        n
    }
}

/// Deterministic init matching the LM artifact's parameter count (JAX-side
/// `ParamSpec.init` is not reachable from Rust; a scaled normal matches its
/// statistics and both optimizers share the same vector).
fn init_params(dim: usize, seed: u64) -> Vec<f32> {
    Rng::new(seed).normal_vec(dim, 0.02)
}

fn write_curves(out_dir: &str, name: &str, logs: &[&RunLog]) -> Result<()> {
    for log in logs {
        let path = format!("{out_dir}/{name}_{}.csv", log.name);
        log.write_csv(&path)?;
    }
    Ok(())
}

/// Build an optimizer with the short-run-scaled β₂ = 0.97 for the
/// Adam-family kinds (see `fig4a` scaling note); SGD-family kinds are
/// unaffected.
fn build_scaled(
    kind: OptimizerKind,
    workers: usize,
    init: Vec<f32>,
    warmup: Option<usize>,
) -> Box<dyn DistOptimizer> {
    use crate::compress::CompressionKind;
    use crate::optim::backend::AdamHyper;
    use crate::optim::zeroone_adam::{ZeroOneAdam, ZeroOneAdamConfig};
    use crate::optim::NaiveCompressedAdam;
    let hyper = AdamHyper { beta2: 0.97, ..AdamHyper::default() };
    match kind {
        OptimizerKind::ZeroOneAdam => Box::new(ZeroOneAdam::new(
            workers,
            init,
            ZeroOneAdamConfig { hyper, ..Default::default() },
        )),
        OptimizerKind::Adam => {
            Box::new(Adam::new(workers, init).with_hyper(hyper))
        }
        OptimizerKind::OneBitAdam => Box::new(OneBitAdam::new(
            workers,
            init,
            OneBitAdamConfig {
                warmup_steps: warmup,
                hyper,
                ..Default::default()
            },
        )),
        OptimizerKind::OneBitAdam32 => Box::new(OneBitAdam::new(
            workers,
            init,
            OneBitAdamConfig {
                warmup_steps: warmup,
                compression: CompressionKind::None,
                hyper,
                ..Default::default()
            },
        )),
        OptimizerKind::OneBitNaive => Box::new(
            NaiveCompressedAdam::new(workers, init).with_hyper(hyper),
        ),
        other => other.build(workers, init, warmup),
    }
}

/// Figure 1: Adam vs naive EC-compressed Adam on the LM task.
pub fn fig1(art: &str, out: &str, fast: bool) -> Result<()> {
    let rt = runtime(art)?;
    let steps = scale(fast, 400);
    let workers = 4;
    let mut logs = Vec::new();
    for kind in [OptimizerKind::Adam, OptimizerKind::OneBitNaive] {
        let mut src = LmSource::new(rt.clone(), "lm-tiny", workers, 7)?;
        let dim = src.dim();
        let mut opt = build_scaled(kind, workers, init_params(dim, 1), None);
        let opts = TrainOptions {
            steps,
            schedule: LrSchedule::Constant(1e-3),
            timing: None,
            log_every: 0,
        };
        let log = train(opt.as_mut(), &mut src, &opts)?;
        println!(
            "  {:<12} final loss {:.4} (tail-20 {:.4})",
            log.name,
            log.final_loss().unwrap(),
            log.tail_loss(20).unwrap()
        );
        logs.push(log);
    }
    write_curves(out, "fig1", &logs.iter().collect::<Vec<_>>())?;
    let adam = logs[0].tail_loss(20).unwrap();
    let naive = logs[1].tail_loss(20).unwrap();
    println!(
        "Fig 1: naive-compressed Adam ends {:+.3} above Adam (paper: \
         visible degradation)",
        naive - adam
    );
    Ok(())
}

/// Figure 2: variance-norm stabilization + the auto-switch indicator.
pub fn fig2(art: &str, out: &str, fast: bool) -> Result<()> {
    use crate::optim::backend::AdamHyper;
    let rt = runtime(art)?;
    let steps = if fast { 400 } else { 1200 };
    let workers = 4;
    let mut src = LmSource::new(rt, "lm-tiny", workers, 11)?;
    let dim = src.dim();
    let hyper = AdamHyper { beta2: 0.97, ..AdamHyper::default() };
    let mut opt = Adam::new(workers, init_params(dim, 2)).with_hyper(hyper);
    let lr_warmup = steps / 10;
    let schedule = LrSchedule::LinearWarmupExpDecay {
        peak: 1e-3,
        warmup: lr_warmup,
        every: 52,
        decay: 0.99,
    };
    let mut monitor =
        crate::optim::VarianceMonitor::new(0.999, 0.96, lr_warmup);
    // Δ matched to the scaled β₂ (0.97 ⇒ Δ ≈ 33).
    let mut monitor_short =
        crate::optim::VarianceMonitor::new(0.97, 0.96, lr_warmup);
    let mut csv = String::from("step,loss,v_norm1,ratio\n");
    let mut switch_at = None;
    for step in 0..steps {
        let mut grads = Vec::with_capacity(workers);
        let mut loss_sum = 0.0;
        for w in 0..workers {
            let (l, g) = src.grad(w, opt.params())?;
            loss_sum += l as f64;
            grads.push(g);
        }
        opt.step(&grads, schedule.lr(step));
        let vnorm = crate::tensor::norm1(opt.variance());
        monitor.observe_norm(vnorm);
        if monitor_short.observe_norm(vnorm) && switch_at.is_none() {
            switch_at = Some(step);
        }
        csv.push_str(&format!(
            "{step},{},{vnorm},{}\n",
            loss_sum / workers as f64,
            monitor_short.ratio().unwrap_or(0.0)
        ));
    }
    std::fs::create_dir_all(out)?;
    std::fs::write(format!("{out}/fig2_vnorm.csv"), csv)?;
    match switch_at {
        Some(s) => println!(
            "Fig 2: ‖v‖₁ stabilized (ratio ≥ 0.96 over Δ window) at step \
             {s}/{steps} — auto-switch would freeze here (paper: 22173 vs \
             hand-tuned 23000 for the full BERT run)"
        ),
        None => println!(
            "Fig 2: variance still drifting after {steps} steps (ratio {:?})",
            monitor_short.ratio()
        ),
    }
    Ok(())
}

/// Figure 4(a): sample-wise convergence, Adam vs 1-bit Adam on the LM.
///
/// Scaling note: the paper's warmup (23K steps) is ~23× the variance
/// timescale 1/(1−β₂)=1000.  A 600-step proxy run must shrink β₂
/// correspondingly (β₂ = 0.97 ⇒ Δ ≈ 33, warmup/Δ ≈ 4.5) or v_{T_w} is
/// frozen long before it stabilizes — the exact failure Figure 2 warns
/// about.  Both optimizers share the scaled β₂ for a fair comparison.
pub fn fig4a(art: &str, out: &str, fast: bool) -> Result<()> {
    use crate::optim::backend::AdamHyper;
    let rt = runtime(art)?;
    let steps = if fast { 800 } else { 2500 };
    let min_warmup = steps / 5;
    let workers = 4;
    let hyper = AdamHyper { beta2: 0.97, ..AdamHyper::default() };
    let schedule = LrSchedule::LinearWarmupExpDecay {
        peak: 1e-3,
        warmup: steps / 10,
        every: steps / 16,
        decay: 0.9,
    };
    let timing = TimingModel {
        net: NetworkModel::ethernet(),
        compute: ComputeModel::bert_large_v100(),
        n_gpus: 64,
        grad_accum: 4,
        // charge BERT-Large-sized traffic on the virtual clock
        params_override: Some(super::timing::BERT_LARGE_PARAMS),
    };
    let mut logs = Vec::new();
    for compressed in [false, true] {
        let mut src = LmSource::new(rt.clone(), "lm-tiny", workers, 13)?;
        let dim = src.dim();
        let mut opt: Box<dyn DistOptimizer> = if compressed {
            // auto-switch: freeze when ‖v‖ stabilizes (paper's criterion)
            Box::new(OneBitAdam::new(
                workers,
                init_params(dim, 3),
                OneBitAdamConfig {
                    warmup_steps: None,
                    min_warmup_steps: min_warmup,
                    hyper,
                    ..Default::default()
                },
            ))
        } else {
            Box::new(Adam::new(workers, init_params(dim, 3)).with_hyper(hyper))
        };
        let opts = TrainOptions {
            steps,
            schedule,
            timing: Some(timing.clone()),
            log_every: 0,
        };
        let log = train(opt.as_mut(), &mut src, &opts)?;
        println!(
            "  {:<10} final {:.4}  tail-30 {:.4}  sim-time {:.0}s  comm {:.1} MB",
            log.name,
            log.final_loss().unwrap(),
            log.tail_loss(30).unwrap(),
            log.sim_time(),
            log.total_comm_bytes() as f64 / 1e6
        );
        logs.push(log);
    }
    write_curves(out, "fig4a", &logs.iter().collect::<Vec<_>>())?;
    let adam = &logs[0];
    let onebit = &logs[1];
    let gap =
        (onebit.tail_loss(30).unwrap() - adam.tail_loss(30).unwrap()).abs();
    println!(
        "Fig 4(a): |1-bit Adam − Adam| tail-loss gap = {gap:.4} (paper: \
         same sample-wise convergence)"
    );
    println!(
        "Fig 4(b) view: sim-time Adam {:.0}s vs 1-bit {:.0}s → {:.2}x; \
         volume reduction {:.1}x",
        adam.sim_time(),
        onebit.sim_time(),
        adam.sim_time() / onebit.sim_time(),
        onebit.volume_reduction_vs(adam)
    );
    Ok(())
}

fn run_cnn_kind(
    rt: Rc<Runtime>,
    label: &str,
    mut opt: Box<dyn DistOptimizer>,
    steps: usize,
    schedule: LrSchedule,
    workers: usize,
    seed: u64,
) -> Result<(RunLog, f32)> {
    let mut src = CnnSource::new(rt.clone(), workers, 4.0, seed)?;
    let opts = TrainOptions { steps, schedule, timing: None, log_every: 0 };
    let mut log = train(opt.as_mut(), &mut src, &opts)?;
    log.name = label.to_string();
    let acc = src.test_accuracy(opt.params(), 999)?;
    Ok((log, acc))
}

/// Figure 6: the five-way optimizer comparison on the CNN substitute.
pub fn fig6(art: &str, out: &str, fast: bool) -> Result<()> {
    let rt = runtime(art)?;
    let steps = scale(fast, 500);
    let workers = 8;
    // paper: 13 of 200 epochs; floor at two beta2=0.97 windows (66 steps)
    // so v_{T_w} is meaningful in the scaled-down run (see fig4a note)
    let warmup = (steps * 13 / 200).max(66);
    // paper: lr 0.1 for SGD, 1e-4 for the Adam family, /10 every 100 epochs
    let decay_every = steps / 2;
    let mut rows = Vec::new();
    let mut logs = Vec::new();
    let configs: Vec<(&str, OptimizerKind, f32)> = vec![
        ("SGD", OptimizerKind::Sgd, 0.1),
        ("Adam", OptimizerKind::Adam, 1e-3),
        ("1-bit Adam", OptimizerKind::OneBitAdam, 1e-3),
        ("1-bit Adam (32b)", OptimizerKind::OneBitAdam32, 1e-3),
        ("Adam (1-bit Naive)", OptimizerKind::OneBitNaive, 1e-3),
    ];
    let dim = {
        let spec = rt.manifest().get("cnn_train_step").unwrap();
        spec.inputs[0].elements()
    };
    for (label, kind, lr) in configs {
        let opt = build_scaled(kind, workers, init_params(dim, 4), Some(warmup));
        let schedule = LrSchedule::StepDecay {
            base: lr,
            every: decay_every,
            factor: 0.1,
        };
        let (log, acc) =
            run_cnn_kind(rt.clone(), label, opt, steps, schedule, workers, 21)?;
        println!(
            "  {:<20} final loss {:.4}  test acc {:.3}",
            label,
            log.tail_loss(20).unwrap(),
            acc
        );
        rows.push((label.to_string(), log.tail_loss(20).unwrap(), acc));
        logs.push(log);
    }
    write_curves(out, "fig6", &logs.iter().collect::<Vec<_>>())?;
    let get = |name: &str| rows.iter().find(|r| r.0 == name).unwrap().1;
    println!(
        "Fig 6 ordering check: Adam {:.3} ≈ 1-bit {:.3} ≈ 32b {:.3}; naive \
         {:.3} worst (paper: same ordering)",
        get("Adam"),
        get("1-bit Adam"),
        get("1-bit Adam (32b)"),
        get("Adam (1-bit Naive)")
    );
    Ok(())
}

/// Figure 8: GAN — Adam vs 1-bit Adam (20% warmup).
pub fn fig8(art: &str, out: &str, fast: bool) -> Result<()> {
    use crate::coordinator::gan::GanTrainer;
    let rt = runtime(art)?;
    // Fixed horizon: the tiny-GAN proxy is only marginally stable under
    // sign compression (EXPERIMENTS.md records the envelope) — 150 steps
    // at lr 5e-5 with 40% warmup is the comparable-regime configuration;
    // longer horizons eventually collapse the compressed generator.
    let _ = fast;
    let steps = 150;
    let workers = 4;
    let spec = rt.manifest().get("gan_d_step").unwrap().clone();
    let dp = spec.inputs[0].elements();
    let gp = spec.inputs[1].elements();

    let mut csv = String::from("step,run,d_loss,g_loss\n");
    let mut finals = Vec::new();
    // GAN gradient scales shift as D/G co-adapt, so the scaled run uses
    // β₂ = 0.9 (Δ = 10) — the warmup then spans ≥ 6 variance windows,
    // mirroring the paper's 20%-of-many-epochs CelebA setup.
    let hyper = crate::optim::backend::AdamHyper {
        beta2: 0.9,
        ..Default::default()
    };
    for (label, compressed) in [("adam", false), ("1bit-adam", true)] {
        let warmup = steps * 2 / 5;
        let mk = |init: Vec<f32>| -> Box<dyn DistOptimizer> {
            if compressed {
                Box::new(OneBitAdam::new(
                    workers,
                    init,
                    OneBitAdamConfig {
                        warmup_steps: Some(warmup),
                        hyper,
                        ..Default::default()
                    },
                ))
            } else {
                Box::new(Adam::new(workers, init).with_hyper(hyper))
            }
        };
        let mut d_opt = mk(init_params(dp, 5));
        let mut g_opt = mk(init_params(gp, 6));
        let mut trainer = GanTrainer::new(rt.clone(), workers, 31)?;
        let mut last = (0.0f32, 0.0f32);
        for step in 0..steps {
            let rec = trainer.step(
                d_opt.as_mut(),
                g_opt.as_mut(),
                step,
                5e-5,
                5e-5,
            )?;
            csv.push_str(&format!(
                "{step},{label},{},{}\n",
                rec.d_loss, rec.g_loss
            ));
            last = (rec.d_loss, rec.g_loss);
        }
        println!(
            "  {:<10} final D loss {:.4}, G loss {:.4}",
            label, last.0, last.1
        );
        finals.push(last);
    }
    std::fs::create_dir_all(out)?;
    std::fs::write(format!("{out}/fig8_gan.csv"), csv)?;
    println!(
        "Fig 8: |ΔD| = {:.3}, |ΔG| = {:.3} between Adam and 1-bit Adam \
         (paper: nearly identical training curves)",
        (finals[0].0 - finals[1].0).abs(),
        (finals[0].1 - finals[1].1).abs()
    );
    Ok(())
}

/// Figures 10/11: SGD-family communication-efficient baselines.
pub fn fig10(art: &str, out: &str, fast: bool) -> Result<()> {
    comparison_figure(
        art,
        out,
        fast,
        "fig10",
        &[
            ("1-bit Adam", OptimizerKind::OneBitAdam, 1e-3),
            ("DoubleSqueeze", OptimizerKind::DoubleSqueeze, 0.1),
            ("Local SGD", OptimizerKind::LocalSgd, 0.1),
        ],
    )
}

pub fn fig11(art: &str, out: &str, fast: bool) -> Result<()> {
    comparison_figure(
        art,
        out,
        fast,
        "fig11",
        &[
            ("1-bit Adam", OptimizerKind::OneBitAdam, 1e-3),
            ("EF Momentum SGD", OptimizerKind::EfMomentumSgd, 0.1),
            ("Local Momentum", OptimizerKind::LocalMomentumSgd, 0.1),
        ],
    )
}

fn comparison_figure(
    art: &str,
    out: &str,
    fast: bool,
    name: &str,
    configs: &[(&str, OptimizerKind, f32)],
) -> Result<()> {
    let rt = runtime(art)?;
    let steps = scale(fast, 500);
    let workers = 8;
    let warmup = (steps * 13 / 200).max(66);
    let dim = rt.manifest().get("cnn_train_step").unwrap().inputs[0]
        .elements();
    let mut logs = Vec::new();
    for (label, kind, lr) in configs {
        let opt = build_scaled(*kind, workers, init_params(dim, 7), Some(warmup));
        let schedule = LrSchedule::StepDecay {
            base: *lr,
            every: steps / 2,
            factor: 0.1,
        };
        let (log, acc) = run_cnn_kind(
            rt.clone(),
            label,
            opt,
            steps,
            schedule,
            workers,
            41,
        )?;
        println!(
            "  {:<18} final loss {:.4}  acc {:.3}  comm {:.2} MB",
            label,
            log.tail_loss(20).unwrap(),
            acc,
            log.total_comm_bytes() as f64 / 1e6
        );
        logs.push(log);
    }
    write_curves(out, name, &logs.iter().collect::<Vec<_>>())?;
    println!(
        "{name}: all communication-efficient baselines converge \
         (paper: momentum-SGD family can beat 1-bit Adam on vision tasks)"
    );
    Ok(())
}

/// Figure 12: n-bit compressed variance — low n must fail.
pub fn fig12(art: &str, out: &str, fast: bool) -> Result<()> {
    let rt = runtime(art)?;
    let steps = scale(fast, 400);
    let workers = 8;
    let dim = rt.manifest().get("cnn_train_step").unwrap().inputs[0]
        .elements();
    let mut logs = Vec::new();
    // Adam reference (paper's CIFAR lr: 1e-4 for the Adam family)
    let adam: Box<dyn DistOptimizer> =
        Box::new(Adam::new(workers, init_params(dim, 8)));
    let schedule = LrSchedule::Constant(1e-4);
    let (log, _) = run_cnn_kind(
        rt.clone(), "Adam", adam, steps, schedule, workers, 51,
    )?;
    println!("  {:<14} final loss {:.4}", "Adam", log.tail_loss(20).unwrap());
    logs.push(log);
    for bits in [2u32, 4, 8, 16] {
        let opt: Box<dyn DistOptimizer> = Box::new(NBitVarianceAdam::new(
            workers,
            init_params(dim, 8),
            bits,
        ));
        let (log, _) = run_cnn_kind(
            rt.clone(),
            &format!("{bits}-bit variance"),
            opt,
            steps,
            schedule,
            workers,
            51,
        )?;
        let fl = log.tail_loss(20).unwrap();
        println!(
            "  {:<14} final loss {}",
            format!("{bits}-bit var"),
            if fl.is_finite() { format!("{fl:.4}") } else { "DIVERGED".into() }
        );
        logs.push(log);
    }
    write_curves(out, "fig12", &logs.iter().collect::<Vec<_>>())?;
    println!(
        "Fig 12: no n-bit-variance variant tracks Adam (paper: n ≤ 8 cannot \
         converge — reproduced by the un-floored quantizer; with the \
         divide-by-zero floor, coarse v degenerates to momentum-SGD-like \
         preconditioning while accurate v amplifies sign-momentum noise). \
         The paper's conclusion stands: freeze v after warmup instead."
    );
    Ok(())
}

/// Figure 13: lazily-synced variance — must lag Adam.
pub fn fig13(art: &str, out: &str, fast: bool) -> Result<()> {
    let rt = runtime(art)?;
    let steps = scale(fast, 400);
    let workers = 8;
    let dim = rt.manifest().get("cnn_train_step").unwrap().inputs[0]
        .elements();
    let schedule = LrSchedule::Constant(1e-4);
    let mut logs = Vec::new();
    let adam: Box<dyn DistOptimizer> =
        Box::new(Adam::new(workers, init_params(dim, 9)));
    let (log, _) =
        run_cnn_kind(rt.clone(), "Adam", adam, steps, schedule, workers, 61)?;
    println!("  {:<14} final loss {:.4}", "Adam", log.tail_loss(20).unwrap());
    logs.push(log);
    for tau in [4usize, 16, 64] {
        let opt: Box<dyn DistOptimizer> = Box::new(LazyVarianceAdam::new(
            workers,
            init_params(dim, 9),
            tau,
        ));
        let (log, _) = run_cnn_kind(
            rt.clone(),
            &format!("lazy-v tau={tau}"),
            opt,
            steps,
            schedule,
            workers,
            61,
        )?;
        println!(
            "  {:<14} final loss {:.4}",
            format!("lazy τ={tau}"),
            log.tail_loss(20).unwrap()
        );
        logs.push(log);
    }
    write_curves(out, "fig13", &logs.iter().collect::<Vec<_>>())?;
    println!("Fig 13: stale variance hurts convergence (paper: fails)");
    Ok(())
}

/// Table 3: fine-tune quality parity — compressed vs uncompressed
/// pre-training, then a shared fine-tune protocol on k downstream tasks.
pub fn table3(art: &str, out: &str, fast: bool) -> Result<()> {
    let rt = runtime(art)?;
    let pre_steps = if fast { 150 } else { 1200 };
    let ft_steps = scale(fast, 120);
    let workers = 4;
    let seeds = if fast { 3 } else { 5 };

    // Pre-train two checkpoints from the same init.  The paper's decaying
    // schedule matters here: a constant lr leaves the compressed run at
    // its EC noise floor and unfairly degrades its checkpoint.
    let pre_schedule = LrSchedule::LinearWarmupExpDecay {
        peak: 1e-3,
        warmup: pre_steps / 10,
        every: (pre_steps / 16).max(1),
        decay: 0.9,
    };
    let mut checkpoints = Vec::new();
    for kind in [OptimizerKind::Adam, OptimizerKind::OneBitAdam] {
        let mut src = LmSource::new(rt.clone(), "lm-tiny", workers, 71)?;
        let dim = src.dim();
        let mut opt = build_scaled(
            kind,
            workers,
            init_params(dim, 10),
            Some((pre_steps / 4).max(66)),
        );
        let opts = TrainOptions {
            steps: pre_steps,
            schedule: pre_schedule,
            timing: None,
            log_every: 0,
        };
        let log = train(opt.as_mut(), &mut src, &opts)?;
        println!(
            "  pretrain {:<10} loss {:.4}",
            log.name,
            log.tail_loss(20).unwrap()
        );
        checkpoints.push((log.name.clone(), opt.params().to_vec()));
    }

    // Fine-tune each checkpoint on 3 downstream "tasks" (different corpus
    // seeds ⇒ different transition structure), multiple seeds, median.
    let mut t = Table::new(&["task", "uncompressed", "compressed", "gap"]);
    let mut gaps = Vec::new();
    for task in 0..3usize {
        let mut medians = Vec::new();
        for (_, ckpt) in &checkpoints {
            let mut finals = Vec::new();
            for seed in 0..seeds {
                let mut src = LmSource::new(
                    rt.clone(),
                    "lm-tiny",
                    workers,
                    1000 + 7 * task as u64 + seed as u64,
                )?;
                let mut opt = OptimizerKind::Adam.build(
                    workers,
                    ckpt.clone(),
                    None,
                );
                let opts = TrainOptions {
                    steps: ft_steps,
                    schedule: LrSchedule::Constant(5e-4),
                    timing: None,
                    log_every: 0,
                };
                let log = train(opt.as_mut(), &mut src, &opts)?;
                finals.push(log.tail_loss(10).unwrap());
            }
            finals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            medians.push(finals[finals.len() / 2]);
        }
        let gap = medians[1] - medians[0];
        gaps.push(gap);
        t.row(&[
            format!("task-{task}"),
            format!("{:.4}", medians[0]),
            format!("{:.4}", medians[1]),
            format!("{gap:+.4}"),
        ]);
    }
    println!("Table 3 — downstream fine-tune loss (median over {seeds} seeds)");
    println!("{}", t.render());
    let mean_gap: f32 = gaps.iter().sum::<f32>() / gaps.len() as f32;
    println!(
        "mean |gap| = {:.4} (paper: compressed == uncompressed within noise)",
        mean_gap.abs()
    );
    std::fs::create_dir_all(out)?;
    std::fs::write(
        format!("{out}/table3.csv"),
        format!("mean_gap,{mean_gap}\n"),
    )?;
    Ok(())
}

#[cfg(test)]
mod tests {
    //! Convergence-regression tier: the claims this whole module exists to
    //! reproduce — "1-bit Adam matches uncompressed Adam's convergence" —
    //! pinned as assertions on the built-in synthetic problems (no PJRT
    //! artifacts needed), at smoke-sized iteration counts so
    //! `cargo test -q` stays fast.  The stored tolerances below are the
    //! regression contract: a change that pushes 1-bit Adam (flat or
    //! hierarchical topology, 1-bit or 32-bit ablation) outside them
    //! breaks the reproduction even if every structural test still
    //! passes.

    use crate::comm::CommTopology;
    use crate::optim::backend::AdamHyper;
    use crate::optim::onebit_adam::{OneBitAdam, OneBitAdamConfig};
    use crate::optim::oracle::{QuadraticOracle, RippleOracle};
    use crate::optim::zeroone_adam::{ZeroOneAdam, ZeroOneAdamConfig};
    use crate::optim::{Adam, DistOptimizer};
    use crate::util::prng::Rng;

    /// Stored tolerance: 1-bit Adam's final loss may exceed Adam's by at
    /// most this factor on the smoke-sized quadratic runs (both are at
    /// their stochastic noise floors, which differ by the EC quantization
    /// noise — the paper's claim is same *convergence*, not same floor).
    const LOSS_TOL_FACTOR: f64 = 10.0;
    /// Absolute slack added to the factor bound (noise-floor jitter).
    const LOSS_TOL_ABS: f64 = 0.05;
    /// Both optimizers must contract the initial loss by at least this
    /// factor — "within tolerance of Adam" is vacuous if nothing
    /// converged.
    const CONTRACTION: f64 = 0.05;
    /// Stored tolerance for the non-convex (ripple) run, on the final
    /// squared gradient norm (Assumption 1's metric: losses are
    /// basin-dependent on a multi-minimum landscape, gradient norms are
    /// not).
    const GRAD_TOL_FACTOR: f64 = 20.0;
    const GRAD_TOL_ABS: f64 = 1.0;

    const DIM: usize = 128;
    const WORKERS: usize = 8;
    const STEPS: usize = 900;

    fn hyper() -> AdamHyper {
        // Short-run-scaled beta2 (see the fig4a scaling note above).
        AdamHyper { beta2: 0.97, ..AdamHyper::default() }
    }

    fn oracle(seed: u64) -> QuadraticOracle {
        QuadraticOracle::new(DIM, WORKERS, 0.2, 2.0, 0.3, seed)
    }

    fn init(seed: u64) -> Vec<f32> {
        Rng::new(seed).normal_vec(DIM, 1.0)
    }

    /// The shared schedule of the integration convergence suite,
    /// smoke-sized: 10% linear lr warmup, constant, quarter at 60%.
    fn lr_at(t: usize, steps: usize, lr0: f32) -> f32 {
        if t < steps / 10 {
            lr0 * (t + 1) as f32 / (steps / 10) as f32
        } else if t < steps * 6 / 10 {
            lr0
        } else {
            lr0 * 0.25
        }
    }

    fn run_quad(
        opt: &mut dyn DistOptimizer,
        oracle: &mut QuadraticOracle,
        steps: usize,
        lr0: f32,
    ) -> f64 {
        run_quad_tracking_bytes(opt, oracle, steps, lr0).0
    }

    /// [`run_quad`] that also sums the measured per-GPU wire bytes of
    /// every step (the CommStats ledger the volume claims are stated
    /// in).
    fn run_quad_tracking_bytes(
        opt: &mut dyn DistOptimizer,
        oracle: &mut QuadraticOracle,
        steps: usize,
        lr0: f32,
    ) -> (f64, usize) {
        let mut bytes = 0usize;
        for t in 0..steps {
            let grads = oracle.grads(opt.params());
            let stats = opt.step(&grads, lr_at(t, steps, lr0));
            bytes += stats.comm.total_per_gpu();
        }
        (oracle.value(opt.params()), bytes)
    }

    fn onebit_cfg(topology: CommTopology) -> OneBitAdamConfig {
        OneBitAdamConfig {
            warmup_steps: Some(STEPS / 5),
            hyper: hyper(),
            topology,
            ..Default::default()
        }
    }

    #[test]
    fn onebit_final_loss_within_tolerance_of_adam_smoke() {
        let mut adam = Adam::new(WORKERS, init(1)).with_hyper(hyper());
        let f0 = oracle(9).value(&init(1));
        let f_adam = run_quad(&mut adam, &mut oracle(9), STEPS, 2e-2);
        let mut onebit = OneBitAdam::new(
            WORKERS,
            init(1),
            onebit_cfg(CommTopology::Flat),
        );
        let f_onebit = run_quad(&mut onebit, &mut oracle(9), STEPS, 2e-2);
        assert!(
            f_adam < f0 * CONTRACTION,
            "Adam failed to converge: f0={f0} f_adam={f_adam}"
        );
        assert!(
            f_onebit < f0 * CONTRACTION,
            "1-bit Adam failed to converge: f0={f0} f_onebit={f_onebit}"
        );
        assert!(
            f_onebit < f_adam * LOSS_TOL_FACTOR + LOSS_TOL_ABS,
            "1-bit Adam outside stored tolerance: adam={f_adam} \
             onebit={f_onebit}"
        );
    }

    #[test]
    fn thirtytwo_bit_final_loss_within_tolerance_of_adam_smoke() {
        // The "1-bit Adam (32-bits)" ablation: frozen variance,
        // uncompressed momentum — must also track Adam.
        use crate::compress::CompressionKind;
        let mut adam = Adam::new(WORKERS, init(2)).with_hyper(hyper());
        let f0 = oracle(11).value(&init(2));
        let f_adam = run_quad(&mut adam, &mut oracle(11), STEPS, 2e-2);
        let mut opt = OneBitAdam::new(
            WORKERS,
            init(2),
            OneBitAdamConfig {
                compression: CompressionKind::None,
                ..onebit_cfg(CommTopology::Flat)
            },
        );
        let f_32 = run_quad(&mut opt, &mut oracle(11), STEPS, 2e-2);
        assert!(f_adam < f0 * CONTRACTION, "f0={f0} f_adam={f_adam}");
        assert!(f_32 < f0 * CONTRACTION, "f0={f0} f_32={f_32}");
        assert!(
            f_32 < f_adam * LOSS_TOL_FACTOR + LOSS_TOL_ABS,
            "32-bit ablation outside stored tolerance: adam={f_adam} \
             thirtytwo={f_32}"
        );
    }

    #[test]
    fn hierarchical_onebit_final_loss_within_tolerance_smoke() {
        // The two-level collective (per-leader EC state, pipelined leader
        // engine) must preserve the convergence claim, not just the bit
        // identities its property tests pin.
        let mut adam = Adam::new(WORKERS, init(3)).with_hyper(hyper());
        let f0 = oracle(13).value(&init(3));
        let f_adam = run_quad(&mut adam, &mut oracle(13), STEPS, 2e-2);
        let mut onebit = OneBitAdam::new(
            WORKERS,
            init(3),
            onebit_cfg(CommTopology::HierarchicalPipelined {
                group_size: 4,
            }),
        );
        let f_hier = run_quad(&mut onebit, &mut oracle(13), STEPS, 2e-2);
        assert!(f_adam < f0 * CONTRACTION, "f0={f0} f_adam={f_adam}");
        assert!(
            f_hier < f0 * CONTRACTION,
            "hierarchical 1-bit Adam failed to converge: f0={f0} \
             f_hier={f_hier}"
        );
        assert!(
            f_hier < f_adam * LOSS_TOL_FACTOR + LOSS_TOL_ABS,
            "hierarchical 1-bit Adam outside stored tolerance: \
             adam={f_adam} hier={f_hier}"
        );
    }

    #[test]
    fn zeroone_final_loss_and_wire_volume_within_tolerance_smoke() {
        // The 0/1 Adam acceptance pair, pinned as one regression: (a)
        // final loss within the stored tolerance of both Adam and 1-bit
        // Adam on the smoke quadratic, (b) total measured wire volume
        // strictly below 1-bit Adam's with its default warmup — the
        // warmup fp32 term is what 0/1 Adam exists to eliminate.
        let mut adam = Adam::new(WORKERS, init(4)).with_hyper(hyper());
        let f0 = oracle(17).value(&init(4));
        let (f_adam, _) = run_quad_tracking_bytes(
            &mut adam,
            &mut oracle(17),
            STEPS,
            2e-2,
        );
        let mut onebit = OneBitAdam::new(
            WORKERS,
            init(4),
            onebit_cfg(CommTopology::Flat),
        );
        let (f_onebit, bytes_onebit) = run_quad_tracking_bytes(
            &mut onebit,
            &mut oracle(17),
            STEPS,
            2e-2,
        );
        let mut zeroone = ZeroOneAdam::new(
            WORKERS,
            init(4),
            ZeroOneAdamConfig { hyper: hyper(), ..Default::default() },
        );
        let (f_zeroone, bytes_zeroone) = run_quad_tracking_bytes(
            &mut zeroone,
            &mut oracle(17),
            STEPS,
            2e-2,
        );
        assert!(f_adam < f0 * CONTRACTION, "f0={f0} f_adam={f_adam}");
        assert!(
            f_zeroone < f0 * CONTRACTION,
            "0/1 Adam failed to converge: f0={f0} f_zeroone={f_zeroone}"
        );
        assert!(
            f_zeroone < f_adam * LOSS_TOL_FACTOR + LOSS_TOL_ABS,
            "0/1 Adam outside stored tolerance vs Adam: adam={f_adam} \
             zeroone={f_zeroone}"
        );
        assert!(
            f_zeroone < f_onebit * LOSS_TOL_FACTOR + LOSS_TOL_ABS,
            "0/1 Adam outside stored tolerance vs 1-bit Adam: \
             onebit={f_onebit} zeroone={f_zeroone}"
        );
        assert!(
            bytes_zeroone < bytes_onebit,
            "0/1 Adam must move strictly fewer wire bytes: \
             zeroone={bytes_zeroone} onebit={bytes_onebit}"
        );
        // and the margin is the warmup term, not noise: 1-bit Adam pays
        // STEPS/5 full-volume fp32 steps, 0/1 Adam O(log STEPS) resyncs
        // (at this small smoke dimension the fixed 1-bit framing is
        // comparatively fat, so the analytic ratio is ~2.4; production
        // dimensions push it past 5 — see netsim::collectives)
        assert!(
            bytes_onebit as f64 / bytes_zeroone as f64 > 2.0,
            "volume margin collapsed: onebit={bytes_onebit} \
             zeroone={bytes_zeroone}"
        );
    }

    #[test]
    fn onebit_nonconvex_gradnorm_within_tolerance_of_adam_smoke() {
        // Assumption 1 setting: on the multi-minimum ripple landscape the
        // regression metric is the final squared gradient norm (losses
        // depend on which basin a run settles in; stationarity does not).
        let steps = 1000;
        let workers = 4;
        let dim = 64;
        let x0 = Rng::new(6).normal_vec(dim, 2.0);
        let g0 = RippleOracle::new(dim, workers, 0.1, 0.3, 3.0, 5)
            .grad_norm2(&x0);
        let run = |opt: &mut dyn DistOptimizer| {
            let mut oracle =
                RippleOracle::new(dim, workers, 0.1, 0.3, 3.0, 5);
            for t in 0..steps {
                let lr = if t < steps * 6 / 10 { 5e-3 } else { 5e-4 };
                let grads = oracle.grads(opt.params());
                opt.step(&grads, lr);
            }
            oracle.grad_norm2(opt.params())
        };
        let mut adam = Adam::new(workers, x0.clone()).with_hyper(hyper());
        let g_adam = run(&mut adam);
        let mut onebit = OneBitAdam::new(
            workers,
            x0,
            OneBitAdamConfig {
                warmup_steps: Some(steps / 5),
                hyper: hyper(),
                ..Default::default()
            },
        );
        let g_onebit = run(&mut onebit);
        assert!(g_adam < g0 * 0.2, "Adam: g0={g0} g_adam={g_adam}");
        assert!(
            g_onebit < g0 * 0.2,
            "1-bit Adam: g0={g0} g_onebit={g_onebit}"
        );
        assert!(
            g_onebit < g_adam * GRAD_TOL_FACTOR + GRAD_TOL_ABS,
            "1-bit Adam outside stored gradient tolerance: \
             adam={g_adam} onebit={g_onebit}"
        );
    }
}
