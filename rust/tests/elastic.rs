//! Elastic-runner integration tier: rank-failure survival with
//! bit-exact `M−1` re-formation, over real TCP meshes formed by the
//! rendezvous coordinator.
//!
//! Workers here are threads (one real `TcpTransport` endpoint each) so
//! the tier stays hermetic; the separate-PID version of the same
//! acceptance — real processes, a real SIGKILL — is the CI job driving
//! `obadam elastic --spawn M`.

use std::net::SocketAddr;
use std::path::{Path, PathBuf};
use std::time::Duration;

use onebit_adam::coordinator::checkpoint::Checkpoint;
use onebit_adam::netsim::epoch_change_window_bound;
use onebit_adam::optim::freeze::VarianceSyncSchedule;
use onebit_adam::transport::elastic::{
    latest_path, reference_run, run_elastic_worker, step_path, ElasticMode,
    ElasticOptions, ElasticReport,
};
use onebit_adam::transport::{ChaosScenario, Coordinator, RendezvousOptions};
use onebit_adam::util::error::Error;

const DIM: usize = 96;
const STEPS: usize = 10;
const RECV_TIMEOUT: Duration = Duration::from_millis(1200);
const WINDOW: Duration = Duration::from_millis(400);
const STRAGGLE: Duration = Duration::from_millis(3000);

fn test_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("obadam_elastic_test_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn base_opts(mode: ElasticMode, dir: &Path) -> ElasticOptions {
    let mut o = ElasticOptions::new(mode, DIM, STEPS, dir.join("ckpt"));
    o.ckpt_every = 2;
    o.noise = 0.05;
    o.tcp.recv_timeout = RECV_TIMEOUT;
    // Short probe interval: the 1.2 s dead-peer budget then holds four
    // NACK rounds (60/180/420/900 ms), so chaos losses recover well
    // inside it instead of spuriously exhausting the budget, while a
    // genuinely dead rank is still detected within `recv_timeout`.
    o.tcp.attempt_timeout = Duration::from_millis(60);
    o.join_timeout = Duration::from_secs(10);
    o
}

fn coordinator(world: usize) -> Coordinator {
    Coordinator::spawn(
        "127.0.0.1:0",
        RendezvousOptions {
            world,
            min_world: world - 1,
            window: WINDOW,
            join_timeout: Duration::from_secs(10),
        },
    )
    .expect("coordinator")
}

fn launch(
    coord: SocketAddr,
    workers: Vec<ElasticOptions>,
) -> Vec<Result<ElasticReport, Error>> {
    let handles: Vec<_> = workers
        .into_iter()
        .map(|o| std::thread::spawn(move || run_elastic_worker(coord, &o)))
        .collect();
    handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
}

#[test]
#[cfg_attr(miri, ignore = "spawns obadam subprocesses, unsupported under Miri")]
fn failure_free_run_bit_matches_the_in_process_engine() {
    let dir = test_dir("clean");
    let mode = ElasticMode::OneBit { warmup_steps: 3 };
    let coord = coordinator(2);
    let opts = base_opts(mode, &dir);
    let mut workers = vec![opts.clone(), opts.clone()];
    for w in &mut workers {
        w.max_epochs = 1;
    }
    let results = launch(coord.addr(), workers);
    for r in &results {
        let rep = r.as_ref().expect("worker failed");
        assert_eq!(rep.epoch, 1);
        assert_eq!(rep.world, 2);
        assert_eq!(rep.steps_done, STEPS);
    }
    let live = Checkpoint::load(latest_path(&opts.ckpt_dir)).unwrap();
    let reference = reference_run(2, None, &opts).unwrap();
    assert_eq!(live, reference.checkpoint);
    for r in &results {
        let rep = r.as_ref().unwrap();
        assert_eq!(rep.comm_alltoall_bytes, reference.comm_alltoall_bytes);
        assert_eq!(rep.comm_allgather_bytes, reference.comm_allgather_bytes);
    }
    drop(coord);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Chaos × elasticity: under a lossy wire, a straggler rank pushed past
/// the dead-peer budget forces an epoch change; the survivors re-form
/// at `M−1` and their resumed trajectory bit-matches a fresh `M−1` run
/// restored from the same checkpoint.
#[test]
#[cfg_attr(miri, ignore = "spawns obadam subprocesses, unsupported under Miri")]
fn chaos_straggler_epoch_change_bit_matches_fresh_m1_restore() {
    let dir = test_dir("chaos");
    let mode = ElasticMode::OneBit { warmup_steps: 3 };
    let coord = coordinator(3);
    let opts = base_opts(mode, &dir);
    let mut workers = Vec::new();
    for id in 0..3usize {
        let mut w = opts.clone();
        w.chaos = Some(ChaosScenario::lossy(7 + id as u64));
        if id == 2 {
            // The victim: stall at step 5 until everyone's dead-peer
            // budget has burned, then fail terminally (max_epochs 1 is
            // the thread-world analog of a SIGKILL).
            w.straggle_at_step = Some(5);
            w.straggle_for = STRAGGLE;
            w.max_epochs = 1;
        } else {
            w.max_epochs = 3;
        }
        workers.push(w);
    }
    let mut results = launch(coord.addr(), workers);
    let victim = results.pop().unwrap();
    assert!(victim.is_err(), "the straggler must not survive");

    let bound = epoch_change_window_bound(RECV_TIMEOUT, WINDOW, 3);
    let mut survivors_prev_ranks = Vec::new();
    for r in &results {
        let rep = r.as_ref().expect("survivor failed");
        assert_eq!(rep.world, 2, "survivors must re-form at M-1");
        assert_eq!(rep.epoch, 2);
        assert_eq!(rep.epochs_joined, 2);
        assert_eq!(rep.steps_done, STEPS);
        // Straggle hits at step 5; the last completed checkpoint is the
        // compression-phase one at step 4.
        assert_eq!(rep.resume_step, Some(4));
        assert_eq!(rep.departed.len(), 1);
        let rec = rep.recovery_ms.expect("survivor must record recovery");
        assert!(
            rec <= bound.as_secs_f64() * 1e3,
            "recovery {rec:.0} ms above the {:.0} ms bound",
            bound.as_secs_f64() * 1e3
        );
        survivors_prev_ranks = rep.survivors.clone();
    }
    assert_eq!(survivors_prev_ranks.len(), 2);

    // The resumed trajectory must bit-match a fresh M−1 engine restored
    // from the same checkpoint: params, m, v, EC state, and comm.
    let ck = Checkpoint::load(step_path(&opts.ckpt_dir, 4)).unwrap();
    assert_eq!(ck.ec.len(), 6, "compression checkpoint carries 2n EC");
    let reference =
        reference_run(2, Some((&ck, 3, &survivors_prev_ranks)), &opts)
            .unwrap();
    let live = Checkpoint::load(latest_path(&opts.ckpt_dir)).unwrap();
    assert_eq!(live, reference.checkpoint);
    for r in &results {
        let rep = r.as_ref().unwrap();
        assert_eq!(rep.comm_alltoall_bytes, reference.comm_alltoall_bytes);
        assert_eq!(rep.comm_allgather_bytes, reference.comm_allgather_bytes);
    }
    drop(coord);
    let _ = std::fs::remove_dir_all(&dir);
}

/// 0/1 Adam re-entry lands exactly on a variance-resync boundary: the
/// checkpoint cadence *is* the sync schedule, so the re-formed world's
/// first step is a sync step, and the trajectory still bit-matches the
/// in-process restore.
#[test]
#[cfg_attr(miri, ignore = "spawns obadam subprocesses, unsupported under Miri")]
fn zeroone_recovery_resumes_at_a_variance_sync_boundary() {
    let dir = test_dir("zeroone");
    let mode = ElasticMode::ZeroOne { var_sync_base: 1 };
    let coord = coordinator(3);
    let opts = base_opts(mode, &dir);
    let mut workers = Vec::new();
    for id in 0..3usize {
        let mut w = opts.clone();
        if id == 2 {
            w.straggle_at_step = Some(5);
            w.straggle_for = STRAGGLE;
            w.max_epochs = 1;
        } else {
            w.max_epochs = 3;
        }
        workers.push(w);
    }
    let mut results = launch(coord.addr(), workers);
    let victim = results.pop().unwrap();
    assert!(victim.is_err());

    let sched = VarianceSyncSchedule::new(1);
    let mut survivors_prev_ranks = Vec::new();
    let mut resume = 0u64;
    for r in &results {
        let rep = r.as_ref().expect("survivor failed");
        assert_eq!(rep.world, 2);
        assert_eq!(rep.epoch, 2);
        resume = rep.resume_step.expect("survivor must resume");
        assert!(
            sched.is_sync(resume as usize),
            "resume step {resume} is not a variance-sync boundary"
        );
        survivors_prev_ranks = rep.survivors.clone();
    }
    // Straggle at 5 with sync checkpoints at 1, 2, 4: resume from 4.
    assert_eq!(resume, 4);

    let ck = Checkpoint::load(step_path(&opts.ckpt_dir, resume)).unwrap();
    let reference =
        reference_run(2, Some((&ck, 3, &survivors_prev_ranks)), &opts)
            .unwrap();
    let live = Checkpoint::load(latest_path(&opts.ckpt_dir)).unwrap();
    assert_eq!(live, reference.checkpoint);
    drop(coord);
    let _ = std::fs::remove_dir_all(&dir);
}
