//! Integration: short end-to-end training runs through the full
//! three-layer stack (PJRT artifacts → Rust coordinator), asserting the
//! paper's qualitative claims on the real (synthetic-data) workloads.
//!
//! Skips cleanly when `artifacts/` is missing.

use std::rc::Rc;

use onebit_adam::coordinator::{
    train, CnnSource, GradSource, LmSource, LrSchedule, TrainOptions,
};
use onebit_adam::optim::backend::AdamHyper;
use onebit_adam::optim::onebit_adam::{OneBitAdam, OneBitAdamConfig};
use onebit_adam::optim::{Adam, DistOptimizer};
use onebit_adam::runtime::Runtime;
use onebit_adam::util::prng::Rng;

fn runtime() -> Option<Rc<Runtime>> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
        return None;
    }
    Some(Rc::new(Runtime::load(dir).expect("load runtime")))
}

fn hyper() -> AdamHyper {
    AdamHyper { beta2: 0.97, ..AdamHyper::default() }
}

#[test]
#[cfg_attr(miri, ignore = "full training loop is prohibitively slow under Miri")]
fn lm_onebit_adam_reduces_loss_through_both_phases() {
    let Some(rt) = runtime() else { return };
    let workers = 2;
    let steps = 300;
    let mut src = LmSource::new(rt, "lm-tiny", workers, 1).unwrap();
    let dim = src.dim();
    let mut opt = OneBitAdam::new(
        workers,
        Rng::new(2).normal_vec(dim, 0.02),
        OneBitAdamConfig {
            warmup_steps: Some(100),
            hyper: hyper(),
            ..Default::default()
        },
    );
    let opts = TrainOptions {
        steps,
        schedule: LrSchedule::Constant(1e-3),
        timing: None,
        log_every: 0,
    };
    let log = train(&mut opt, &mut src, &opts).unwrap();
    let start = log.records[..10]
        .iter()
        .map(|r| r.loss as f64)
        .sum::<f64>()
        / 10.0;
    let end = log.tail_loss(10).unwrap() as f64;
    assert!(end < start - 0.5, "loss {start:.3} -> {end:.3}");
    assert_eq!(log.warmup_steps(), 100);
    // compression steps must be present and cheap on the wire
    let comp_rec = log
        .records
        .iter()
        .rev()
        .find(|r| r.phase == onebit_adam::optim::Phase::Compression)
        .unwrap();
    let warm_rec = &log.records[0];
    assert!(warm_rec.comm_bytes as f64 / comp_rec.comm_bytes as f64 > 20.0);
}

#[test]
#[cfg_attr(miri, ignore = "full training loop is prohibitively slow under Miri")]
fn lm_deterministic_across_runs() {
    let Some(rt) = runtime() else { return };
    let mut finals = Vec::new();
    for _ in 0..2 {
        let workers = 2;
        let mut src = LmSource::new(rt.clone(), "lm-tiny", workers, 7).unwrap();
        let dim = src.dim();
        let mut opt = OneBitAdam::new(
            workers,
            Rng::new(3).normal_vec(dim, 0.02),
            OneBitAdamConfig {
                warmup_steps: Some(20),
                hyper: hyper(),
                ..Default::default()
            },
        );
        let opts = TrainOptions {
            steps: 50,
            schedule: LrSchedule::Constant(1e-3),
            timing: None,
            log_every: 0,
        };
        let log = train(&mut opt, &mut src, &opts).unwrap();
        finals.push((log.final_loss().unwrap(), opt.params()[0]));
    }
    assert_eq!(finals[0], finals[1], "bit-reproducibility broken");
}

#[test]
#[cfg_attr(miri, ignore = "full training loop is prohibitively slow under Miri")]
fn cnn_adam_vs_onebit_parity_short() {
    let Some(rt) = runtime() else { return };
    let workers = 4;
    let steps = 160;
    let dim = rt
        .manifest()
        .get("cnn_train_step")
        .unwrap()
        .inputs[0]
        .elements();
    let init = Rng::new(4).normal_vec(dim, 0.02);

    let mut results = Vec::new();
    for compressed in [false, true] {
        let mut src = CnnSource::new(rt.clone(), workers, 4.0, 21).unwrap();
        let mut opt: Box<dyn DistOptimizer> = if compressed {
            Box::new(OneBitAdam::new(
                workers,
                init.clone(),
                OneBitAdamConfig {
                    warmup_steps: Some(70),
                    hyper: hyper(),
                    ..Default::default()
                },
            ))
        } else {
            Box::new(Adam::new(workers, init.clone()).with_hyper(hyper()))
        };
        let opts = TrainOptions {
            steps,
            schedule: LrSchedule::Constant(1e-3),
            timing: None,
            log_every: 0,
        };
        let log = train(opt.as_mut(), &mut src, &opts).unwrap();
        let acc = src.test_accuracy(opt.params(), 999).unwrap();
        results.push((log.tail_loss(15).unwrap(), acc));
    }
    let (adam_loss, adam_acc) = results[0];
    let (ob_loss, ob_acc) = results[1];
    assert!(
        (ob_loss - adam_loss).abs() < 0.4,
        "loss gap too large: adam {adam_loss} vs 1bit {ob_loss}"
    );
    assert!(
        ob_acc > adam_acc - 0.12,
        "accuracy gap: adam {adam_acc} vs 1bit {ob_acc}"
    );
}

#[test]
#[cfg_attr(miri, ignore = "full training loop is prohibitively slow under Miri")]
fn gan_both_optimizers_stay_finite() {
    let Some(rt) = runtime() else { return };
    use onebit_adam::coordinator::gan::GanTrainer;
    let spec = rt.manifest().get("gan_d_step").unwrap().clone();
    let dp = spec.inputs[0].elements();
    let gp = spec.inputs[1].elements();
    let workers = 2;
    let h = AdamHyper { beta2: 0.9, ..AdamHyper::default() };
    for compressed in [false, true] {
        let mk = |init: Vec<f32>| -> Box<dyn DistOptimizer> {
            if compressed {
                Box::new(OneBitAdam::new(
                    workers,
                    init,
                    OneBitAdamConfig {
                        warmup_steps: Some(40),
                        hyper: h,
                        ..Default::default()
                    },
                ))
            } else {
                Box::new(Adam::new(workers, init).with_hyper(h))
            }
        };
        let mut d = mk(Rng::new(5).normal_vec(dp, 0.02));
        let mut g = mk(Rng::new(6).normal_vec(gp, 0.02));
        let mut tr = GanTrainer::new(rt.clone(), workers, 31).unwrap();
        let mut last = (0.0f32, 0.0f32);
        for t in 0..100 {
            let r = tr.step(d.as_mut(), g.as_mut(), t, 5e-5, 5e-5).unwrap();
            last = (r.d_loss, r.g_loss);
        }
        assert!(
            last.0.is_finite() && last.1.is_finite(),
            "GAN losses must stay finite (compressed={compressed}): {last:?}"
        );
    }
}
