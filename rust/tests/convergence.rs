//! Integration: optimizer convergence claims on controlled oracles —
//! the paper's qualitative findings as assertions.

use onebit_adam::compress::CompressionKind;
use onebit_adam::optim::backend::AdamHyper;
use onebit_adam::optim::onebit_adam::{OneBitAdam, OneBitAdamConfig};
use onebit_adam::optim::oracle::{QuadraticOracle, RippleOracle};
use onebit_adam::optim::{
    Adam, DistOptimizer, DoubleSqueeze, EfMomentumSgd, LocalSgd,
    NaiveCompressedAdam,
};
use onebit_adam::util::prng::Rng;

const D: usize = 128;
const WORKERS: usize = 8;

fn run(opt: &mut dyn DistOptimizer, oracle: &mut QuadraticOracle,
       steps: usize, lr0: f32) -> f64 {
    for t in 0..steps {
        // 10%-linear-warmup + quarter-at-60% schedule, shared by all runs
        let lr = if t < steps / 10 {
            lr0 * (t + 1) as f32 / (steps / 10) as f32
        } else if t < steps * 6 / 10 {
            lr0
        } else {
            lr0 * 0.25
        };
        let grads = oracle.grads(opt.params());
        opt.step(&grads, lr);
    }
    oracle.value(opt.params())
}

fn oracle(seed: u64) -> QuadraticOracle {
    QuadraticOracle::new(D, WORKERS, 0.2, 2.0, 0.3, seed)
}

fn init(seed: u64) -> Vec<f32> {
    Rng::new(seed).normal_vec(D, 1.0)
}

fn hyper() -> AdamHyper {
    AdamHyper { beta2: 0.97, ..AdamHyper::default() }
}

/// Figure 4(a) claim: 1-bit Adam matches Adam's sample-wise convergence.
#[test]
#[cfg_attr(miri, ignore = "full training loop is prohibitively slow under Miri")]
fn onebit_adam_matches_adam_on_quadratic() {
    let steps = 3000;
    let mut adam = Adam::new(WORKERS, init(1)).with_hyper(hyper());
    let f_adam = run(&mut adam, &mut oracle(9), steps, 2e-2);

    let mut onebit = OneBitAdam::new(
        WORKERS,
        init(1),
        OneBitAdamConfig {
            warmup_steps: Some(steps / 5),
            hyper: hyper(),
            ..Default::default()
        },
    );
    let f_onebit = run(&mut onebit, &mut oracle(9), steps, 2e-2);
    assert!(
        f_onebit < f_adam * 10.0 + 1e-4,
        "1-bit Adam should track Adam: adam={f_adam} onebit={f_onebit}"
    );
    assert!(f_onebit < 0.05, "must actually converge: {f_onebit}");
}

/// Figure 1/6 claim: naive gradient compression is strictly worse.  The
/// damage shows on anisotropic curvature (1-bit gradients destroy the
/// per-coordinate scale information Adam's variance needs), so this oracle
/// spans a 200x spectrum.
#[test]
#[cfg_attr(miri, ignore = "full training loop is prohibitively slow under Miri")]
fn naive_compression_lags_both() {
    // Mid-training comparison (constant lr, no anneal): the naive variant's
    // handicap is a slower descent — with enough decay both settle into
    // similar floors, which is not the regime Figure 1 plots.
    let steps = 400;
    let run_const = |opt: &mut dyn DistOptimizer| {
        let mut o = QuadraticOracle::new(D, WORKERS, 0.02, 4.0, 0.05, 10);
        for _ in 0..steps {
            let grads = o.grads(opt.params());
            opt.step(&grads, 2e-2);
        }
        o.value(opt.params())
    };
    let mut adam = Adam::new(WORKERS, init(2)).with_hyper(hyper());
    let f_adam = run_const(&mut adam);
    let mut naive =
        NaiveCompressedAdam::new(WORKERS, init(2)).with_hyper(hyper());
    let f_naive = run_const(&mut naive);
    assert!(
        f_naive > f_adam * 1.5,
        "naive should lag: adam={f_adam} naive={f_naive}"
    );
}

/// The "32-bits" ablation: freezing v alone (no compression) converges.
#[test]
#[cfg_attr(miri, ignore = "full training loop is prohibitively slow under Miri")]
fn frozen_variance_uncompressed_converges() {
    let steps = 2000;
    let mut opt = OneBitAdam::new(
        WORKERS,
        init(3),
        OneBitAdamConfig {
            warmup_steps: Some(400),
            compression: CompressionKind::None,
            hyper: hyper(),
            ..Default::default()
        },
    );
    let f = run(&mut opt, &mut oracle(11), steps, 2e-2);
    assert!(f < 0.05, "32-bit variant failed to converge: {f}");
}

/// Supplementary Figures 10/11: the SGD-family baselines all converge on
/// the (well-conditioned-enough) oracle.
#[test]
#[cfg_attr(miri, ignore = "full training loop is prohibitively slow under Miri")]
fn sgd_family_baselines_converge() {
    let steps = 2500;
    let mut ds = DoubleSqueeze::new(WORKERS, init(4));
    let f_ds = run(&mut ds, &mut oracle(12), steps, 5e-2);
    assert!(f_ds < 0.5, "DoubleSqueeze: {f_ds}");

    let mut ef = EfMomentumSgd::new(WORKERS, init(4), 0.9);
    let f_ef = run(&mut ef, &mut oracle(12), steps, 5e-2);
    assert!(f_ef < 0.5, "EF-momentum: {f_ef}");

    let mut ls = LocalSgd::new(WORKERS, init(4), 4, 0.9);
    let f_ls = run(&mut ls, &mut oracle(12), steps, 5e-2);
    assert!(f_ls < 0.5, "Local momentum SGD: {f_ls}");
}

/// Non-convex sanity (Assumption 1 setting): 1-bit Adam drives the
/// gradient norm down on the ripple oracle.
#[test]
#[cfg_attr(miri, ignore = "full training loop is prohibitively slow under Miri")]
fn onebit_adam_on_nonconvex_ripple() {
    let mut oracle = RippleOracle::new(64, 4, 0.1, 0.3, 3.0, 5);
    let x0 = Rng::new(6).normal_vec(64, 2.0);
    let g0 = oracle.grad_norm2(&x0);
    let mut opt = OneBitAdam::new(
        4,
        x0,
        OneBitAdamConfig {
            warmup_steps: Some(200),
            hyper: hyper(),
            ..Default::default()
        },
    );
    for t in 0..2000 {
        let lr = if t < 1200 { 5e-3 } else { 5e-4 };
        let grads = oracle.grads(opt.params());
        opt.step(&grads, lr);
    }
    let g1 = oracle.grad_norm2(opt.params());
    assert!(
        g1 < g0 * 0.05,
        "gradient norm should collapse: {g0} -> {g1}"
    );
}

/// Volume claim: 1-bit Adam's measured end-to-end traffic matches the
/// 1/(w + (1−w)/32) fp32 formula within 20%.
#[test]
#[cfg_attr(miri, ignore = "full training loop is prohibitively slow under Miri")]
fn measured_volume_matches_formula() {
    let steps = 500;
    let warmup = 100;
    let dim = 40_000;
    let mut onebit = OneBitAdam::new(
        4,
        vec![0.1; dim],
        OneBitAdamConfig {
            warmup_steps: Some(warmup),
            hyper: hyper(),
            ..Default::default()
        },
    );
    let mut adam = Adam::new(4, vec![0.1; dim]).with_hyper(hyper());
    let mut o = QuadraticOracle::new(dim, 4, 0.5, 1.0, 0.1, 99);
    let mut total_1bit = 0usize;
    let mut total_adam = 0usize;
    for _ in 0..steps {
        let g = o.grads(onebit.params());
        total_1bit += onebit.step(&g, 1e-3).comm.total_per_gpu();
        let g = o.grads(adam.params());
        total_adam += adam.step(&g, 1e-3).comm.total_per_gpu();
    }
    let measured = total_adam as f64 / total_1bit as f64;
    let w = warmup as f64 / steps as f64;
    // per-step compressed ratio vs fp32 ≈ 32 (minus headers)
    let formula = 1.0 / (w + (1.0 - w) / 32.0);
    assert!(
        (measured / formula - 1.0).abs() < 0.2,
        "measured {measured:.2} vs formula {formula:.2}"
    );
}
