//! Tier-1 tests for `obadam analyze` — the first-party invariant
//! linter.
//!
//! Two halves:
//! * seeded-violation fixtures (in-memory sources through
//!   [`analyze::scan_source`]) proving every pass fires and every
//!   suppression mechanism works, and
//! * the full-tree scan over this crate's own sources, which must be
//!   clean and fast — the same gate `obadam analyze` enforces in CI.
//!
//! The fixtures live in raw strings on purpose: the analyzer lexes
//! string literals as opaque tokens, so the violations seeded here are
//! invisible to the full-tree scan below.  (That property is itself
//! asserted: the scan of `tests/analyze.rs` yields nothing.)

use onebit_adam::analyze::{self, report::Finding};

fn rules(findings: &[Finding]) -> Vec<&str> {
    findings.iter().map(|f| f.rule.as_str()).collect()
}

// ---- hot-path-alloc --------------------------------------------------------

#[test]
fn hot_path_alloc_fires_on_each_forbidden_form() {
    let src = r#"
// lint: hot-path
fn kernel(x: &mut Vec<f32>) {
    let a = Vec::new();
    let b = vec![0.0f32; 8];
    let c = x.clone();
    let d = format!("{a:?}{b:?}{c:?}");
    let e = Box::new(0u32);
    let f = String::from("x");
    let g = x.to_vec();
}
// lint: end
"#;
    let got = analyze::scan_source("src/comm/fixture.rs", src);
    let hot: Vec<&Finding> = got
        .iter()
        .filter(|f| f.rule == "hot-path-alloc")
        .collect();
    assert_eq!(hot.len(), 7, "one per seeded allocation: {got:?}");
    let lines: Vec<u32> = hot.iter().map(|f| f.line).collect();
    assert_eq!(lines, [4, 5, 6, 7, 8, 9, 10]);
}

#[test]
fn hot_path_alloc_ignores_code_outside_fences() {
    let src = r#"
fn setup() -> Vec<f32> {
    let mut v = Vec::new();
    v.push(1.0);
    v.clone()
}
"#;
    assert!(analyze::scan_source("src/comm/fixture.rs", src).is_empty());
}

#[test]
fn hot_path_alloc_allow_comment_suppresses() {
    let src = r#"
// lint: hot-path
fn kernel() {
    // lint: allow(hot-path-alloc): one-time init, measured cold.
    let a = Vec::new();
    let b: Vec<u32> = a;
    drop(b);
}
// lint: end
"#;
    assert!(analyze::scan_source("src/comm/fixture.rs", src).is_empty());
}

#[test]
fn hot_path_unbalanced_fences_are_findings() {
    let unclosed = "// lint: hot-path\nfn f() {}\n";
    let got = analyze::scan_source("src/comm/fixture.rs", unclosed);
    assert_eq!(rules(&got), ["hot-path-alloc"]);
    assert!(got[0].message.contains("unclosed"));

    let stray = "fn f() {}\n// lint: end\n";
    let got = analyze::scan_source("src/comm/fixture.rs", stray);
    assert_eq!(rules(&got), ["hot-path-alloc"]);
    assert!(got[0].message.contains("without an open"));
}

// ---- safety-comment --------------------------------------------------------

#[test]
fn safety_comment_fires_on_bare_unsafe() {
    let src = r#"
fn peek(p: *const u8) -> u8 {
    unsafe { *p }
}
"#;
    let got = analyze::scan_source("src/util/fixture.rs", src);
    assert_eq!(rules(&got), ["safety-comment"]);
    assert_eq!(got[0].line, 3);
}

#[test]
fn safety_comment_satisfied_by_nearby_comment() {
    let src = r#"
fn peek(p: *const u8) -> u8 {
    // SAFETY: caller guarantees `p` is valid for reads.
    unsafe { *p }
}
"#;
    assert!(analyze::scan_source("src/util/fixture.rs", src).is_empty());
}

#[test]
fn safety_comment_window_does_not_reach_across_items() {
    let src = r#"
// SAFETY: this comment is too far above to vouch for the block.
fn a() {}
fn b() {}
fn c() {}
fn d() {}
fn e() {}
fn f() {}
fn g() {}
fn h() {}
fn peek(p: *const u8) -> u8 {
    unsafe { *p }
}
"#;
    let got = analyze::scan_source("src/util/fixture.rs", src);
    assert_eq!(rules(&got), ["safety-comment"]);
}

// ---- ledger-exhaustive -----------------------------------------------------

#[test]
fn ledger_exhaustive_fires_on_rest_pattern() {
    let src = r#"
fn ingest(s: &CommStats) -> u64 {
    let CommStats { bits_sent, .. } = *s;
    bits_sent
}
"#;
    let got = analyze::scan_source("src/trace/fixture.rs", src);
    assert_eq!(rules(&got), ["ledger-exhaustive"]);
    assert_eq!(got[0].line, 3);
    assert!(got[0].message.contains("CommStats"));
}

#[test]
fn ledger_exhaustive_accepts_exhaustive_and_functional_update() {
    let src = r#"
fn ingest(s: &TransportStats) -> u64 {
    let TransportStats { frames, bytes } = *s;
    frames + bytes
}
fn grow(s: TransportStats) -> TransportStats {
    TransportStats { frames: s.frames + 1, ..s }
}
impl RecoveryStats {
    fn reset(&mut self) {}
}
struct CommStats {
    bits_sent: u64,
}
"#;
    assert!(analyze::scan_source("src/trace/fixture.rs", src).is_empty());
}

#[test]
fn ledger_exhaustive_ignores_nested_rest_on_other_types() {
    // The `..` belongs to the nested non-ledger pattern, not to the
    // ledger destructure itself.
    let src = r#"
fn f(s: Wrapper) {
    let Wrapper { inner: CommStats { bits_sent }, other: Other { .. } } =
        s;
    let _ = bits_sent;
}
"#;
    assert!(analyze::scan_source("src/trace/fixture.rs", src).is_empty());
}

// ---- determinism -----------------------------------------------------------

#[test]
fn determinism_flags_hash_collections_in_src_only() {
    let src = r#"
use std::collections::HashMap;
fn f() -> HashMap<u32, u32> {
    HashMap::new()
}
"#;
    let got = analyze::scan_source("src/metrics/fixture.rs", src);
    assert_eq!(rules(&got), ["hash-collections"; 3]);
    // Test regions and non-src files hash freely.
    let test_src = r#"
fn lib_code() {}
#[cfg(test)]
mod tests {
    use std::collections::HashSet;
    fn t() -> HashSet<u8> {
        HashSet::new()
    }
}
"#;
    assert!(analyze::scan_source("src/metrics/fixture.rs", test_src)
        .is_empty());
    assert!(!analyze::scan_source("tests/fixture.rs", src)
        .iter()
        .any(|f| f.rule == "hash-collections"));
}

#[test]
fn determinism_flags_f32_running_sums_in_numeric_dirs() {
    let turbofish = r#"
fn norm(x: &[f32]) -> f32 {
    x.iter().map(|v| v.abs()).sum::<f32>()
}
"#;
    let got = analyze::scan_source("src/compress/fixture.rs", turbofish);
    assert_eq!(rules(&got), ["float-accum"]);

    let accum = r#"
fn total(x: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for v in x {
        acc += v;
    }
    acc
}
"#;
    let got = analyze::scan_source("src/optim/fixture.rs", accum);
    assert_eq!(rules(&got), ["float-accum"]);
    assert_eq!(got[0].line, 5, "flagged at the `+=`, not the `let`");

    // The blessed pattern — f64 accumulator — is clean, and kernels/
    // (home of the pairwise tree reduce) is exempt by directory.
    let blessed = r#"
fn total(x: &[f32]) -> f32 {
    let mut acc = 0.0f64;
    for v in x {
        acc += *v as f64;
    }
    acc as f32
}
"#;
    assert!(analyze::scan_source("src/optim/fixture.rs", blessed)
        .is_empty());
    assert!(analyze::scan_source("src/kernels/fixture.rs", accum)
        .is_empty());
}

#[test]
fn determinism_flags_timing_outside_allowlist() {
    let src = r#"
use std::time::Instant;
fn step() -> f64 {
    let t0 = Instant::now();
    t0.elapsed().as_secs_f64()
}
"#;
    let got = analyze::scan_source("src/optim/fixture.rs", src);
    assert_eq!(rules(&got), ["timing"]);
    assert_eq!(got[0].line, 4, "`use` alone is not a wall-clock read");
    // trace/ owns time; an allow fence justifies a deadline site.
    assert!(analyze::scan_source("src/trace/fixture.rs", src).is_empty());
    let allowed = r#"
use std::time::Instant;
fn dial() {
    // lint: allow(timing): socket dial deadline, justified.
    let deadline = Instant::now();
    let _ = deadline;
}
"#;
    assert!(analyze::scan_source("src/transport/fixture.rs", allowed)
        .is_empty());
}

// ---- the real tree ---------------------------------------------------------

#[test]
#[cfg_attr(miri, ignore = "reads the filesystem, blocked by Miri isolation")]
fn full_tree_scan_is_clean_and_fast() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let t0 = std::time::Instant::now();
    let report = analyze::run_all(root).expect("scan");
    let elapsed = t0.elapsed();
    assert!(
        report.clean(),
        "shipped tree must be lint-clean:\n{}",
        report.render_text()
    );
    assert!(
        report.files_scanned > 50,
        "suspiciously few files scanned: {}",
        report.files_scanned
    );
    assert!(
        elapsed.as_secs_f64() < 5.0,
        "full-tree scan took {elapsed:?} (budget 5 s)"
    );
}

#[test]
#[cfg_attr(miri, ignore = "reads the filesystem, blocked by Miri isolation")]
fn report_json_round_trips_through_util_json() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = analyze::run_all(root).expect("scan");
    let text = report.to_json().to_string_pretty();
    let back = onebit_adam::util::json::Json::parse(&text).expect("parse");
    assert!(back.get("clean").unwrap().as_bool().unwrap());
    assert_eq!(
        back.usize_of("files_scanned").unwrap(),
        report.files_scanned
    );
    assert_eq!(back.arr_of("findings").unwrap().len(), 0);
    assert!(back.f64_of("scan_ms").unwrap() >= 0.0);
}

#[test]
#[cfg_attr(miri, ignore = "reads the filesystem, blocked by Miri isolation")]
fn seeded_fixtures_in_this_file_are_invisible_to_the_tree_scan() {
    // The fixtures above hold violations inside raw strings; the lexer
    // must treat them as opaque literals when scanning this very file.
    let text = std::fs::read_to_string(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/tests/analyze.rs"
    ))
    .expect("read self");
    assert!(analyze::scan_source("tests/analyze.rs", &text).is_empty());
}
