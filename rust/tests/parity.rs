//! Integration: the AOT artifacts (L1 Pallas + L2 JAX, compiled via PJRT)
//! must agree with the native Rust mirrors — the contract that lets the
//! convergence sweeps use native math while the E2E drivers use the real
//! three-layer path.
//!
//! Requires `make artifacts` (skips cleanly when absent so `cargo test`
//! works on a fresh checkout).

use onebit_adam::compress::onebit::onebit_compress;
use onebit_adam::optim::backend::{
    AdamHyper, MathBackend, NativeBackend, PjrtBackend,
};
use onebit_adam::runtime::Runtime;
use onebit_adam::tensor::max_abs_diff;
use onebit_adam::util::prng::Rng;
use std::rc::Rc;

const N: usize = 65536; // the kernel-test artifact size

fn runtime() -> Option<Rc<Runtime>> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
        return None;
    }
    Some(Rc::new(Runtime::load(dir).expect("load runtime")))
}

#[test]
#[cfg_attr(miri, ignore = "loads HLO artifacts from the filesystem (Miri isolation)")]
fn onebit_compress_artifact_matches_native() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(0);
    let val = rng.normal_vec(N, 1.0);
    let err = rng.normal_vec(N, 0.3);
    let (q_pjrt, e_pjrt, s_pjrt) =
        rt.onebit_compress(N, &val, &err).expect("pjrt compress");
    let (q_nat, e_nat, s_nat) = onebit_compress(&val, &err);
    assert!((s_pjrt - s_nat).abs() / s_nat < 1e-5, "{s_pjrt} vs {s_nat}");
    assert!(max_abs_diff(&q_pjrt, &q_nat) < 1e-5);
    assert!(max_abs_diff(&e_pjrt, &e_nat) < 1e-4);
}

#[test]
#[cfg_attr(miri, ignore = "loads HLO artifacts from the filesystem (Miri isolation)")]
fn adam_step_artifact_matches_native() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(1);
    let p0 = rng.normal_vec(N, 1.0);
    let m0 = rng.normal_vec(N, 0.1);
    let v0: Vec<f32> =
        rng.normal_vec(N, 0.01).iter().map(|x| x.abs()).collect();
    let g = rng.normal_vec(N, 1.0);

    let (p1, m1, v1) =
        rt.adam_step(N, &p0, &m0, &v0, &g, 1e-3).expect("pjrt adam");

    let mut p2 = p0.clone();
    let mut m2 = m0.clone();
    let mut v2 = v0.clone();
    NativeBackend
        .adam_step(AdamHyper::default(), &mut p2, &mut m2, &mut v2, &g, 1e-3)
        .unwrap();
    assert!(max_abs_diff(&p1, &p2) < 1e-5, "p diff");
    assert!(max_abs_diff(&m1, &m2) < 1e-6, "m diff");
    assert!(max_abs_diff(&v1, &v2) < 1e-6, "v diff");
}

#[test]
#[cfg_attr(miri, ignore = "loads HLO artifacts from the filesystem (Miri isolation)")]
fn momentum_and_precond_artifacts_match_native() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(2);
    let m0 = rng.normal_vec(N, 0.1);
    let g = rng.normal_vec(N, 1.0);
    let m1 = rt.momentum_update(N, &m0, &g).expect("pjrt momentum");
    let mut m2 = m0.clone();
    NativeBackend.momentum_update(0.9, &mut m2, &g).unwrap();
    assert!(max_abs_diff(&m1, &m2) < 1e-6);

    let p0 = rng.normal_vec(N, 1.0);
    let vf: Vec<f32> =
        rng.normal_vec(N, 1.0).iter().map(|x| x.abs() + 1e-3).collect();
    let p1 = rt.precond_step(N, &p0, &m1, &vf, 1e-3).expect("pjrt precond");
    let mut p2 = p0.clone();
    NativeBackend.precond_step(1e-8, &mut p2, &m1, &vf, 1e-3).unwrap();
    assert!(max_abs_diff(&p1, &p2) < 1e-5);
}

#[test]
#[cfg_attr(miri, ignore = "loads HLO artifacts from the filesystem (Miri isolation)")]
fn pjrt_backend_trait_object_works() {
    let Some(rt) = runtime() else { return };
    let backend = PjrtBackend::new(rt);
    let mut rng = Rng::new(3);
    let mut p = rng.normal_vec(N, 1.0);
    let p0 = p.clone();
    let mut m = vec![0.0f32; N];
    let mut v = vec![0.0f32; N];
    let g = rng.normal_vec(N, 1.0);
    backend
        .adam_step(AdamHyper::default(), &mut p, &mut m, &mut v, &g, 1e-3)
        .unwrap();
    assert!(max_abs_diff(&p, &p0) > 0.0);
    // non-default hyperparameters must be rejected, not silently wrong
    let bad = AdamHyper { beta1: 0.5, ..AdamHyper::default() };
    assert!(backend
        .adam_step(bad, &mut p, &mut m, &mut v, &g, 1e-3)
        .is_err());
}

#[test]
#[cfg_attr(miri, ignore = "loads HLO artifacts from the filesystem (Miri isolation)")]
fn lm_train_step_loss_is_sane_and_grads_flow() {
    let Some(rt) = runtime() else { return };
    let spec = rt
        .manifest()
        .get("lm_train_step_lm-tiny")
        .expect("lm-tiny artifact")
        .clone();
    let p_count = spec.inputs[0].elements();
    let batch = spec.inputs[1].shape[0];
    let seq = spec.inputs[1].shape[1];
    let vocab = spec.meta_usize("vocab").unwrap();

    // deterministic init mirroring ParamSpec.init is not required here —
    // a small random init suffices for loss sanity
    let mut rng = Rng::new(4);
    let params = rng.normal_vec(p_count, 0.02);
    let tokens: Vec<i32> =
        (0..batch * seq).map(|_| rng.below(vocab as u64) as i32).collect();
    let (loss, grads) = rt
        .train_step("lm_train_step_lm-tiny", &params, &tokens, &tokens)
        .expect("train step");
    // random init ⇒ loss near ln(vocab)
    let uniform = (vocab as f32).ln();
    assert!(
        (loss - uniform).abs() < 1.5,
        "loss {loss} vs uniform {uniform}"
    );
    assert_eq!(grads.len(), p_count);
    assert!(grads.iter().all(|g| g.is_finite()));
    let gnorm: f64 =
        grads.iter().map(|&g| (g as f64) * (g as f64)).sum::<f64>().sqrt();
    assert!(gnorm > 1e-3, "gradient flow, |g|={gnorm}");
}

#[test]
#[cfg_attr(miri, ignore = "loads HLO artifacts from the filesystem (Miri isolation)")]
fn cnn_train_step_descends_with_pjrt_adam() {
    // Mini end-to-end: 5 Adam steps on the CNN artifact must reduce loss on
    // a fixed batch — all compute through PJRT, no Python.
    let Some(rt) = runtime() else { return };
    let spec = rt.manifest().get("cnn_train_step").expect("cnn").clone();
    let p_count = spec.inputs[0].elements();
    let batch = spec.inputs[1].shape[0];
    let in_dim = spec.inputs[1].shape[1];
    let classes = spec.meta_usize("classes").unwrap();

    let mut rng = Rng::new(5);
    let mut params = rng.normal_vec(p_count, 0.05);
    let x: Vec<f32> = rng.normal_vec(batch * in_dim, 1.0);
    let y: Vec<i32> =
        (0..batch).map(|_| rng.below(classes as u64) as i32).collect();

    let mut m = vec![0.0f32; p_count];
    let mut v = vec![0.0f32; p_count];
    let (loss0, _) =
        rt.cnn_step("cnn_train_step", &params, &x, &y).unwrap();
    for _ in 0..15 {
        let (_, g) = rt.cnn_step("cnn_train_step", &params, &x, &y).unwrap();
        let (pn, mn, vn) =
            rt.adam_step(p_count, &params, &m, &v, &g, 1e-2).unwrap();
        params = pn;
        m = mn;
        v = vn;
    }
    let (loss1, _) = rt.cnn_step("cnn_train_step", &params, &x, &y).unwrap();
    assert!(loss1 < loss0 - 0.2, "loss {loss0} -> {loss1}");
}

#[test]
#[cfg_attr(miri, ignore = "loads HLO artifacts from the filesystem (Miri isolation)")]
fn gan_artifacts_execute() {
    let Some(rt) = runtime() else { return };
    let spec = rt.manifest().get("gan_d_step").expect("gan").clone();
    let dp = spec.inputs[0].elements();
    let gp = spec.inputs[1].elements();
    let batch = spec.inputs[2].shape[0];
    let data_dim = spec.inputs[2].shape[1];
    let z_dim = spec.inputs[3].shape[1];
    let mut rng = Rng::new(6);
    let d = rng.normal_vec(dp, 0.05);
    let g = rng.normal_vec(gp, 0.05);
    let real = rng.normal_vec(batch * data_dim, 0.5);
    let z = rng.normal_vec(batch * z_dim, 1.0);
    let (dl, dg) = rt.gan_d_step(&d, &g, &real, &z).unwrap();
    let (gl, gg) = rt.gan_g_step(&d, &g, &z).unwrap();
    assert!(dl.is_finite() && gl.is_finite());
    assert_eq!(dg.len(), dp);
    assert_eq!(gg.len(), gp);
    // fresh discriminator ⇒ D loss near 2·ln 2, G loss near ln 2
    assert!((dl - 2.0 * 0.6931).abs() < 0.5, "d loss {dl}");
    assert!((gl - 0.6931).abs() < 0.4, "g loss {gl}");
}

#[test]
#[cfg_attr(miri, ignore = "loads HLO artifacts from the filesystem (Miri isolation)")]
fn input_validation_rejects_wrong_shapes() {
    let Some(rt) = runtime() else { return };
    let bad = vec![0.0f32; 7];
    assert!(rt.onebit_compress(N, &bad, &bad).is_err());
    assert!(rt.execute("no_such_artifact", &[]).is_err());
}
