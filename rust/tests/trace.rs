//! Trace-subsystem integration tier.
//!
//! These tests live in their own binary (not `src/trace/` unit tests)
//! because the recording gate is **process-global**: flipping it inside
//! the lib test binary would race the comm/optim suites'
//! zero-allocation assertions running on sibling harness threads.
//! Here the binary owns the gate, installs its own counting global
//! allocator (the lib's is `cfg(test)`-only and absent in integration
//! builds), and serializes every recording test behind one local mutex.

use std::net::SocketAddr;
use std::path::{Path, PathBuf};
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

use onebit_adam::comm::overlap::OverlapConfig;
use onebit_adam::compress::CompressionKind;
use onebit_adam::netsim::collectives::overlapped_step_time;
use onebit_adam::netsim::epoch_change_window_bound;
use onebit_adam::optim::onebit_adam::{OneBitAdam, OneBitAdamConfig};
use onebit_adam::optim::DistOptimizer;
use onebit_adam::trace::{self, analysis, SpanKind, Trace};
use onebit_adam::transport::chaos::{
    FAULT_AUX_CORRUPT, FAULT_AUX_DROP, NACK_AUX_SENT, NACK_AUX_SERVED,
};
use onebit_adam::transport::elastic::{
    run_elastic_worker, ElasticMode, ElasticOptions, ElasticReport,
};
use onebit_adam::transport::{
    ChaosScenario, Coordinator, RendezvousOptions, TcpOptions,
    TransportBackend, TransportCollective,
};
use onebit_adam::util::alloc_track::{
    current_thread_allocs, CountingAllocator,
};
use onebit_adam::util::error::Error;
use onebit_adam::util::json::Json;
use onebit_adam::util::prng::Rng;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

/// One recording test at a time: the gate, the collector, and the
/// overflow counter are process-global.
static GATE: Mutex<()> = Mutex::new(());

fn gate() -> MutexGuard<'static, ()> {
    let g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    trace::clear();
    g
}

fn stop_and_take() -> Trace {
    trace::disable();
    trace::take()
}

#[test]
fn ring_overwrites_oldest_and_drains_on_thread_exit() {
    let _g = gate();
    trace::enable_with_capacity(16);
    // A fresh thread gets a fresh ring sized by the current capacity
    // (the harness may reuse this test's own thread across tests), and
    // its ring must drain into the collector when the thread exits.
    std::thread::spawn(|| {
        trace::set_rank(5);
        for i in 0..40u64 {
            trace::instant(SpanKind::ChaosFault, i);
        }
    })
    .join()
    .unwrap();
    let tr = stop_and_take();
    let auxes: Vec<u64> =
        tr.instants(SpanKind::ChaosFault).map(|e| e.aux).collect();
    // 40 recorded into a 16-slot ring: survivors are the newest 16 in
    // record order, and the 24 overwrites are accounted.
    assert_eq!(auxes, (24..40).collect::<Vec<u64>>());
    assert_eq!(trace::dropped(), 24);
    assert_eq!(tr.ranks_with(SpanKind::ChaosFault), [5].into());
    trace::clear();
}

#[test]
fn ring_overflow_and_drain_on_the_recording_thread() {
    // Miri-targeted twin of the test above: no helper thread, so the
    // interpreter checks the ring's overwrite arithmetic and the
    // live-thread drain path `take()` uses (`flush_thread`) without
    // paying for a thread spawn.
    let _g = gate();
    trace::enable_with_capacity(16);
    trace::set_rank(3);
    for i in 0..40u64 {
        trace::instant(SpanKind::ChaosFault, i);
    }
    let tr = stop_and_take();
    let auxes: Vec<u64> =
        tr.instants(SpanKind::ChaosFault).map(|e| e.aux).collect();
    assert_eq!(auxes, (24..40).collect::<Vec<u64>>());
    assert_eq!(trace::dropped(), 24);
    assert_eq!(tr.ranks_with(SpanKind::ChaosFault), [3].into());
    // The rank tag outlives the drained ring; restore the driver tag in
    // case the harness reuses this thread for a later recording test.
    trace::set_rank(trace::DRIVER_RANK as usize);
    trace::clear();
}

#[test]
fn recording_hot_path_does_not_allocate() {
    let _g = gate();
    trace::enable_with_capacity(8192);
    // Pay the ring's one-time reserve (and the epoch init) before the
    // measured region.
    trace::instant(SpanKind::ChaosFault, 0);
    let before = current_thread_allocs();
    for i in 0..2000u64 {
        let mut sp = trace::span_aux(SpanKind::Compress, i);
        sp.set_aux(i + 1);
        drop(sp);
        trace::instant(SpanKind::NackRetransmit, i);
        trace::counter(SpanKind::WireBytes, i);
    }
    let after = current_thread_allocs();
    assert_eq!(after, before, "hot-path recording allocated");
    let tr = stop_and_take();
    assert_eq!(tr.spans(SpanKind::Compress).count(), 2000);
    trace::clear();
}

/// The flagship acceptance run: 8 ranks, transported compressed
/// collectives, overlapped bucket pipeline.  One capture must cover
/// every wire-path span kind with a per-rank track, reconcile the
/// trace-derived overlap bubble against the netsim recurrence, and
/// round-trip through both export formats.
#[test]
#[cfg_attr(miri, ignore = "multi-rank fan-out is prohibitively slow under Miri")]
fn transported_overlapped_run_covers_kinds_and_reconciles_overlap() {
    let _g = gate();
    trace::enable_with_capacity(1 << 15);
    let workers = 8;
    let dim = 2048;
    let steps = 4;
    let mut opt = OneBitAdam::new(
        workers,
        Rng::new(11).normal_vec(dim, 0.05),
        OneBitAdamConfig {
            warmup_steps: Some(1),
            transport: Some(TransportBackend::InMemory),
            overlap: Some(OverlapConfig::default()),
            ..Default::default()
        },
    );
    let mut rng = Rng::new(12);
    for _ in 0..steps {
        let grads: Vec<Vec<f32>> =
            (0..workers).map(|_| rng.normal_vec(dim, 0.1)).collect();
        opt.step(&grads, 1e-3);
    }
    let tr = stop_and_take();

    // Kind coverage: every stage of the compressed exchange, the
    // pipeline scheduler, and the optimizer kernel left spans.
    let kinds = tr.kinds_present();
    for kind in [
        SpanKind::Compress,
        SpanKind::PackVote,
        SpanKind::WireSend,
        SpanKind::WireRecv,
        SpanKind::ServerReduce,
        SpanKind::Broadcast,
        SpanKind::AdamKernel,
        SpanKind::Step,
        SpanKind::BucketCompute,
        SpanKind::BucketComm,
        SpanKind::WireBytes,
    ] {
        assert!(kinds.contains(&kind), "no {} events", kind.name());
    }
    // Per-rank tracks: all 8 transport ranks recorded the wire stages.
    let all_ranks: std::collections::BTreeSet<u32> = (0..8).collect();
    for kind in [SpanKind::WireSend, SpanKind::WireRecv, SpanKind::Compress]
    {
        assert_eq!(
            tr.ranks_with(kind),
            all_ranks,
            "missing rank tracks for {}",
            kind.name()
        );
    }

    // Overlap reconciliation: the driver's bucket spans, fed through
    // the same recurrence netsim uses, must bound the measured window.
    let reports = analysis::overlap_report(&tr, trace::DRIVER_RANK);
    // warmup step 0 has no buckets; compression steps 1..4 do.
    assert_eq!(reports.len(), steps - 1, "one report per pipelined step");
    for r in &reports {
        assert_eq!(r.compute_ns.len(), 4, "default bucket count");
        let compute: Vec<f64> =
            r.compute_ns.iter().map(|&x| x as f64).collect();
        let comm: Vec<f64> = r.comm_ns.iter().map(|&x| x as f64).collect();
        let model = overlapped_step_time(&compute, &comm);
        assert_eq!(model, r.modeled_ns(), "report must use the netsim model");
        assert!(
            r.measured_ns as f64 >= model * 0.999,
            "measured window {} ns beat the recurrence bound {} ns",
            r.measured_ns,
            model
        );
        for frac in [
            r.bubble_fraction(),
            r.modeled_bubble_fraction(),
            r.overlap_efficiency(),
        ] {
            assert!((0.0..=1.0).contains(&frac), "fraction {frac}");
        }
    }
    assert!(!analysis::overlap_table(&reports).render().is_empty());

    // Straggler attribution: WireRecv waits attribute to a real peer.
    let stragglers = analysis::straggler_report(&tr);
    let worst = stragglers.straggler().expect("recv waits were recorded");
    assert!(worst < workers as u32, "straggler {worst} is not a rank");

    // Chrome export parses and keeps one span per instrumented stage;
    // the binary dump round-trips exactly.
    let chrome = Json::parse(&tr.to_chrome_string()).unwrap();
    let events = chrome.arr_of("traceEvents").unwrap();
    assert!(events.len() >= tr.len());
    for name in ["Compress", "WireSend", "BucketComm", "Step"] {
        assert!(
            events.iter().any(|e| {
                e.str_of("name").map(|n| n == name).unwrap_or(false)
            }),
            "chrome JSON lost {name}"
        );
    }
    assert_eq!(Trace::from_binary(&tr.to_binary()).unwrap(), tr);
    trace::clear();
}

#[test]
#[cfg_attr(miri, ignore = "multi-rank fan-out is prohibitively slow under Miri")]
fn chaos_faults_and_nack_recovery_leave_instant_markers() {
    let _g = gate();
    trace::enable_with_capacity(1 << 15);
    let scenario = ChaosScenario::acceptance(0xC0FFEE);
    let workers = 4;
    let len = 777;
    let tcp = TcpOptions {
        attempt_timeout: Duration::from_millis(250),
        recv_timeout: Duration::from_secs(20),
        ..TcpOptions::default()
    };
    let mut car = TransportCollective::with_chaos(
        TransportBackend::InMemory,
        workers,
        len,
        CompressionKind::OneBit,
        1,
        &tcp,
        &scenario,
    )
    .unwrap();
    let mut out = vec![0.0f32; len];
    let base = Rng::new(41_000);
    for step in 0..3u64 {
        let inputs: Vec<Vec<f32>> = (0..workers)
            .map(|w| {
                base.fork(step * 100 + w as u64).normal_vec(len, 1.0)
            })
            .collect();
        car.allreduce(&inputs, &mut out);
    }
    let rec = car.recovery_stats();
    let tr = stop_and_take();

    // Every injected drop/corruption left an instant at its injection
    // site, tagged with the fault class.
    let count = |aux: u64| {
        tr.instants(SpanKind::ChaosFault)
            .filter(|e| e.aux == aux)
            .count() as u64
    };
    assert!(rec.injected_drops > 0, "scenario injected nothing: {rec:?}");
    assert_eq!(count(FAULT_AUX_DROP), rec.injected_drops);
    assert_eq!(count(FAULT_AUX_CORRUPT), rec.injected_corruptions);
    // Recovery markers: drops force NACK probes and replayed frames.
    let nack = |aux: u64| {
        tr.instants(SpanKind::NackRetransmit)
            .filter(|e| e.aux == aux)
            .count() as u64
    };
    assert!(rec.retransmits_served > 0, "no repair work: {rec:?}");
    assert!(nack(NACK_AUX_SENT) > 0, "no NACK-sent markers");
    assert_eq!(nack(NACK_AUX_SERVED), rec.retransmits_served);
    trace::clear();
}

// ---- elastic recovery timeline ---------------------------------------------

const DIM: usize = 96;
const STEPS: usize = 10;
const RECV_TIMEOUT: Duration = Duration::from_millis(1200);
const WINDOW: Duration = Duration::from_millis(400);
const STRAGGLE: Duration = Duration::from_millis(3000);

fn test_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("obadam_trace_test_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn base_opts(mode: ElasticMode, dir: &Path) -> ElasticOptions {
    let mut o = ElasticOptions::new(mode, DIM, STEPS, dir.join("ckpt"));
    o.ckpt_every = 2;
    o.noise = 0.05;
    o.tcp.recv_timeout = RECV_TIMEOUT;
    o.tcp.attempt_timeout = Duration::from_millis(60);
    o.join_timeout = Duration::from_secs(10);
    o
}

fn launch(
    coord: SocketAddr,
    workers: Vec<ElasticOptions>,
) -> Vec<Result<ElasticReport, Error>> {
    let handles: Vec<_> = workers
        .into_iter()
        .map(|o| std::thread::spawn(move || run_elastic_worker(coord, &o)))
        .collect();
    handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
}

/// Failure → rendezvous → restore timeline: the survivors' measured
/// recovery windows, read straight off the trace, must sit under the
/// netsim closed-form epoch-change bound.
#[test]
#[cfg_attr(miri, ignore = "real sockets are unsupported under Miri")]
fn elastic_recovery_window_reconciles_with_the_netsim_bound() {
    let _g = gate();
    trace::enable_with_capacity(1 << 14);
    let dir = test_dir("recovery");
    let coord = Coordinator::spawn(
        "127.0.0.1:0",
        RendezvousOptions {
            world: 3,
            min_world: 2,
            window: WINDOW,
            join_timeout: Duration::from_secs(10),
        },
    )
    .expect("coordinator");
    let opts = base_opts(ElasticMode::OneBit { warmup_steps: 3 }, &dir);
    let mut workers = Vec::new();
    for id in 0..3usize {
        let mut w = opts.clone();
        if id == 2 {
            // The victim is the highest rank, so the survivors keep
            // their ranks across the M−1 re-formation and the per-rank
            // timeline in the trace stays contiguous.
            w.straggle_at_step = Some(5);
            w.straggle_for = STRAGGLE;
            w.max_epochs = 1;
        } else {
            w.max_epochs = 3;
        }
        workers.push(w);
    }
    let mut results = launch(coord.addr(), workers);
    let victim = results.pop().unwrap();
    assert!(victim.is_err(), "the straggler must not survive");
    for r in &results {
        assert_eq!(r.as_ref().expect("survivor failed").world, 2);
    }
    let tr = stop_and_take();

    for kind in [
        SpanKind::RendezvousEpoch,
        SpanKind::CheckpointWrite,
        SpanKind::CheckpointRestore,
        SpanKind::Step,
    ] {
        assert!(
            tr.kinds_present().contains(&kind),
            "no {} events",
            kind.name()
        );
    }
    assert!(tr.instants(SpanKind::PeerFailure).count() >= 2);

    let bound = epoch_change_window_bound(RECV_TIMEOUT, WINDOW, 3);
    let reports = analysis::recovery_report(&tr);
    // One timeline per survivor; the victim never re-rendezvoused, so
    // it contributes no report.
    assert_eq!(reports.len(), 2, "reports: {reports:?}");
    for r in &reports {
        assert!(r.rank < 2, "victim rank {} in the report", r.rank);
        assert!(r.rendezvous_ns() > 0, "empty rendezvous: {r:?}");
        assert!(r.total_ns() > 0);
        assert!(
            r.within_bound(bound),
            "rank {} recovered in {:.1} ms, bound {:.1} ms",
            r.rank,
            r.total_ns() as f64 / 1e6,
            bound.as_secs_f64() * 1e3,
        );
        assert!(!r.to_table().render().is_empty());
    }
    drop(coord);
    let _ = std::fs::remove_dir_all(&dir);
    trace::clear();
}
