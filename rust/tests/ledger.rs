//! Ledger property tests: every `CommStats` producer must satisfy
//! `alltoall + allgather == modeled ring / chunk-scan total`, so drift
//! like the odd-byte ring split (`comm/plain.rs`) or the dropped
//! momentum-round baseline (`optim/local_sgd.rs`) cannot silently
//! recur.  The models here are written as independent arithmetic — the
//! ring total `2·(len·4)·(n−1)/n` and the chunk-scan convention
//! "all-to-all sends every chunk but one's own (`total − min`),
//! all-gather broadcasts the largest owned chunk (`max`)" — and checked
//! byte-exactly against what the engines actually return.

use onebit_adam::comm::overlap::{OverlapConfig, OverlapPipeline};
use onebit_adam::comm::plain::allreduce_average;
use onebit_adam::comm::{
    chunk_wire_volume, Collective, CommStats, CommTopology,
};
use onebit_adam::compress::CompressionKind;
use onebit_adam::optim::{DistOptimizer, LocalSgd};
use onebit_adam::tensor::chunk::ChunkLayout;
use onebit_adam::transport::{
    RecoveryStats, TransportBackend, TransportCollective, TransportStats,
};
use onebit_adam::util::prng::Rng;

/// Per-GPU payload of an fp32 ring allreduce — the plain engines'
/// contract.
fn ring_total(n: usize, len: usize) -> usize {
    if n > 1 {
        2 * (len * 4) * (n - 1) / n
    } else {
        0
    }
}

/// Per-GPU (alltoall, allgather) payload of a compressed collective
/// over `n` chunks — the chunk-scan contract shared by every
/// compressed engine.
fn chunk_model(
    kind: CompressionKind,
    n: usize,
    len: usize,
) -> (usize, usize) {
    let layout = ChunkLayout::new(len, n);
    let (total, min, max) = chunk_wire_volume(kind, &layout);
    (total - min, max)
}

fn rand_inputs(seed: u64, n: usize, len: usize) -> Vec<Vec<f32>> {
    let base = Rng::new(seed);
    (0..n)
        .map(|i| base.fork(i as u64).normal_vec(len, 1.0))
        .collect()
}

/// The length sweep: every small length (where the odd-byte and
/// short-chunk corner cases live) plus a stride across the full
/// 0..=4096 range.
fn length_sweep() -> Vec<usize> {
    let mut lens: Vec<usize> = (0..=130).collect();
    lens.extend((131..4096).step_by(89));
    lens.push(4095);
    lens.push(4096);
    lens
}

#[test]
fn plain_split_sums_to_the_ring_total_everywhere() {
    for n in 1..=8usize {
        for len in length_sweep() {
            let inputs: Vec<Vec<f32>> =
                (0..n).map(|_| vec![0.5f32; len]).collect();
            let mut out = vec![0.0f32; len];
            let s = allreduce_average(&inputs, &mut out);
            assert_eq!(
                s.total_per_gpu(),
                ring_total(n, len),
                "plain n={n} len={len}"
            );
            assert_eq!(s.uncompressed_bytes, len * 4);
        }
    }
}

#[test]
fn flat_compressed_stats_match_the_chunk_scan_model() {
    for kind in [
        CompressionKind::None,
        CompressionKind::OneBit,
        CompressionKind::NBit(8),
        CompressionKind::NBit(4),
    ] {
        for n in 1..=8usize {
            for len in length_sweep() {
                let mut car =
                    Collective::build(CommTopology::Flat, n, len, kind);
                let inputs = rand_inputs(7, n, len);
                let mut out = vec![0.0f32; len];
                let s = car.allreduce(&inputs, &mut out);
                let (a2a, ag) = chunk_model(kind, n, len);
                assert_eq!(
                    (s.alltoall_bytes_per_gpu, s.allgather_bytes_per_gpu),
                    (a2a, ag),
                    "{kind:?} n={n} len={len}"
                );
                assert_eq!(s.uncompressed_bytes, len * 4);
            }
        }
    }
}

#[test]
fn hierarchical_stats_are_the_leader_count_chunk_scan() {
    // Stage 2 runs the flat collective over L = ⌈n/g⌉ leaders, so the
    // reported wire volume is the chunk model at the *leader* count.
    let kind = CompressionKind::OneBit;
    for n in 1..=8usize {
        for g in 1..=4usize {
            for len in [0usize, 1, 5, 63, 64, 257, 1024, 4096] {
                let mut car = Collective::build(
                    CommTopology::Hierarchical { group_size: g },
                    n,
                    len,
                    kind,
                );
                let inputs = rand_inputs(11, n, len);
                let mut out = vec![0.0f32; len];
                let s = car.allreduce(&inputs, &mut out);
                let leaders = n.div_ceil(g.clamp(1, n.max(1)));
                let (a2a, ag) = chunk_model(kind, leaders, len);
                assert_eq!(
                    (s.alltoall_bytes_per_gpu, s.allgather_bytes_per_gpu),
                    (a2a, ag),
                    "n={n} g={g} len={len}"
                );
            }
        }
    }
}

#[test]
#[cfg_attr(miri, ignore = "multi-rank fan-out is prohibitively slow under Miri")]
fn transported_stats_match_the_in_process_ledger() {
    // The runner computes its ledger independently (closed form over
    // the frames it actually sends); it must agree with the chunk-scan
    // and ring models byte-exactly.  Smaller grid — each config spins
    // up a rank-per-thread mesh.
    for kind in [CompressionKind::None, CompressionKind::OneBit] {
        for (n, len) in [
            (1usize, 64usize),
            (2, 0),
            (2, 1),
            (3, 65),
            (4, 10),
            (5, 1001),
            (8, 4097),
        ] {
            let mut wire =
                TransportCollective::new(TransportBackend::InMemory, n, len, kind)
                    .expect("in-memory mesh");
            let inputs = rand_inputs(13, n, len);
            let mut out = vec![0.0f32; len];
            let s = wire.allreduce(&inputs, &mut out);
            let (a2a, ag) = chunk_model(kind, n, len);
            assert_eq!(
                (s.alltoall_bytes_per_gpu, s.allgather_bytes_per_gpu),
                (a2a, ag),
                "compressed {kind:?} n={n} len={len}"
            );
            let p = wire.plain_average(&inputs, &mut out);
            assert_eq!(
                p.total_per_gpu(),
                ring_total(n, len),
                "plain {kind:?} n={n} len={len}"
            );
        }
    }
}

#[test]
#[cfg_attr(miri, ignore = "multi-rank fan-out is prohibitively slow under Miri")]
fn overlap_pipeline_ledger_is_the_per_bucket_sum() {
    // The new producer: a bucketed step's merged CommStats must equal
    // the chunk model summed over its buckets (each bucket is its own
    // collective over its own sub-layout).
    let kind = CompressionKind::OneBit;
    for n in [1usize, 2, 4, 8] {
        for len in [0usize, 1, 64, 257, 1000, 4096] {
            for nb in [1usize, 3, 4] {
                let cfg = OverlapConfig { n_buckets: nb, ..Default::default() };
                let mut pipe = OverlapPipeline::build(
                    &cfg,
                    CommTopology::Flat,
                    n,
                    len,
                    kind,
                    None,
                );
                let inputs = rand_inputs(17, n, len);
                let mut out = vec![0.0f32; len];
                let s = pipe.allreduce(&inputs, &mut out);
                let buckets = ChunkLayout::new(len, nb.max(1).min(len.max(1)));
                let (mut a2a, mut ag, mut unc) = (0usize, 0usize, 0usize);
                for k in 0..buckets.n {
                    let (a, g) = chunk_model(kind, n, buckets.size(k));
                    a2a += a;
                    ag += g;
                    unc += buckets.size(k) * 4;
                }
                assert_eq!(
                    (s.alltoall_bytes_per_gpu, s.allgather_bytes_per_gpu),
                    (a2a, ag),
                    "n={n} len={len} nb={nb}"
                );
                assert_eq!(s.uncompressed_bytes, unc);
                assert_eq!(unc, len * 4, "buckets must tile the tensor");
            }
        }
    }
}

#[test]
fn local_sgd_ledger_matches_the_tau_round_model() {
    // tau−1 silent steps (zero wire bytes, full fp32 baseline), then an
    // averaging round that moves one plain ring — or two, with the
    // momentum variant, whose uncompressed baseline must also double
    // (the PR's LocalSgd ledger bugfix).
    let (n, d, tau) = (4usize, 999usize, 4usize);
    for beta in [0.0f32, 0.9] {
        let mut opt = LocalSgd::new(n, vec![0.2; d], tau, beta);
        let mut rng = Rng::new(23);
        for t in 1..=3 * tau {
            let grads: Vec<Vec<f32>> =
                (0..n).map(|_| rng.normal_vec(d, 1.0)).collect();
            let s = opt.step(&grads, 1e-2).comm;
            let rounds = if beta > 0.0 { 2 } else { 1 };
            if t % tau == 0 {
                assert_eq!(
                    s.total_per_gpu(),
                    rounds * ring_total(n, d),
                    "beta={beta} t={t}: averaging round"
                );
                assert_eq!(
                    s.uncompressed_bytes,
                    rounds * d * 4,
                    "beta={beta} t={t}: fp32 baseline counts every round"
                );
            } else {
                assert_eq!(
                    s.total_per_gpu(),
                    0,
                    "beta={beta} t={t}: local step moves no bytes"
                );
                assert_eq!(
                    s.uncompressed_bytes,
                    d * 4,
                    "beta={beta} t={t}: baseline still accrues"
                );
            }
        }
    }
}

/// A randomized `RecoveryStats` with every field nonzero (so a merge
/// impl that drops a field cannot pass by luck).
fn rand_recovery(rng: &mut Rng) -> RecoveryStats {
    RecoveryStats {
        frames_injected: 1 + rng.below(1000),
        injected_drops: 1 + rng.below(1000),
        injected_corruptions: 1 + rng.below(1000),
        injected_reorders: 1 + rng.below(1000),
        injected_delays: 1 + rng.below(1000),
        forced_clean: 1 + rng.below(1000),
        checksum_failures: 1 + rng.below(1000),
        gaps_detected: 1 + rng.below(1000),
        nacks_sent: 1 + rng.below(1000),
        retransmits_served: 1 + rng.below(1000),
        retransmit_bytes: 1 + rng.below(1000),
        duplicates_discarded: 1 + rng.below(1000),
        control_frames: 1 + rng.below(1000),
        control_bytes: 1 + rng.below(1000),
        nack_misses: 1 + rng.below(1000),
    }
}

fn rand_comm(rng: &mut Rng) -> CommStats {
    CommStats {
        alltoall_bytes_per_gpu: 1 + rng.below(1000) as usize,
        allgather_bytes_per_gpu: 1 + rng.below(1000) as usize,
        uncompressed_bytes: 1 + rng.below(1000) as usize,
    }
}

fn rand_transport(rng: &mut Rng) -> TransportStats {
    TransportStats {
        comm: rand_comm(rng),
        gross_alltoall_bytes: 1 + rng.below(1000) as usize,
        gross_allgather_bytes: 1 + rng.below(1000) as usize,
        gross_intra_bytes: 1 + rng.below(1000) as usize,
        frames_sent: 1 + rng.below(1000) as usize,
    }
}

/// Merge must be exactly fieldwise addition for every ledger — checked
/// over randomized stats with all fields nonzero, both orders, plus the
/// identity (merging a default changes nothing).
#[test]
fn ledger_merges_are_fieldwise_addition_over_randomized_stats() {
    let mut rng = Rng::new(0x1ed6e5);
    for _ in 0..25 {
        // CommStats.
        let (a, b) = (rand_comm(&mut rng), rand_comm(&mut rng));
        let mut ab = a;
        ab.merge(b);
        let mut ba = b;
        ba.merge(a);
        assert_eq!(ab, ba, "CommStats merge must commute");
        assert_eq!(
            ab.total_per_gpu(),
            a.total_per_gpu() + b.total_per_gpu()
        );
        assert_eq!(
            ab.uncompressed_bytes,
            a.uncompressed_bytes + b.uncompressed_bytes
        );
        let mut id = a;
        id.merge(CommStats::default());
        assert_eq!(id, a, "merging a default CommStats is the identity");

        // TransportStats.
        let (a, b) = (rand_transport(&mut rng), rand_transport(&mut rng));
        let mut ab = a;
        ab.merge(&b);
        let mut ba = b;
        ba.merge(&a);
        assert_eq!(ab, ba, "TransportStats merge must commute");
        assert_eq!(ab.gross_total(), a.gross_total() + b.gross_total());
        assert_eq!(ab.frames_sent, a.frames_sent + b.frames_sent);
        assert_eq!(
            ab.comm.total_per_gpu(),
            a.comm.total_per_gpu() + b.comm.total_per_gpu()
        );
        let mut id = a;
        id.merge(&TransportStats::default());
        assert_eq!(id, a, "merging a default TransportStats is the identity");

        // RecoveryStats.
        let (a, b) = (rand_recovery(&mut rng), rand_recovery(&mut rng));
        let mut ab = a;
        ab.merge(&b);
        let mut ba = b;
        ba.merge(&a);
        assert_eq!(ab, ba, "RecoveryStats merge must commute");
        assert_eq!(
            ab.injected_faults(),
            a.injected_faults() + b.injected_faults()
        );
        assert_eq!(ab.nack_misses, a.nack_misses + b.nack_misses);
        assert_eq!(ab.control_bytes, a.control_bytes + b.control_bytes);
        let mut id = a;
        id.merge(&RecoveryStats::default());
        assert_eq!(id, a, "merging a default RecoveryStats is the identity");
    }
}
