//! Integration: row-by-row validation of the netsim against the paper's
//! own Table 1 measurements (the calibration contract of DESIGN.md §2).

use onebit_adam::netsim::collectives::fp16_allreduce_time;
use onebit_adam::netsim::{ComputeModel, NetworkModel};

const BERT_LARGE: usize = 340_000_000;

struct Row {
    ethernet: bool,
    gpus: usize,
    batch1: bool,
    accum: usize,
    paper_allreduce_ms: f64,
    paper_pct: f64,
}

const ROWS: &[Row] = &[
    Row { ethernet: true, gpus: 64, batch1: true, accum: 1, paper_allreduce_ms: 2205.86, paper_pct: 94.0 },
    Row { ethernet: true, gpus: 64, batch1: false, accum: 1, paper_allreduce_ms: 2275.43, paper_pct: 93.0 },
    Row { ethernet: true, gpus: 64, batch1: false, accum: 4, paper_allreduce_ms: 2259.36, paper_pct: 83.0 },
    Row { ethernet: true, gpus: 32, batch1: false, accum: 1, paper_allreduce_ms: 2173.35, paper_pct: 93.0 },
    Row { ethernet: true, gpus: 16, batch1: false, accum: 1, paper_allreduce_ms: 2133.24, paper_pct: 92.0 },
    Row { ethernet: true, gpus: 8, batch1: false, accum: 1, paper_allreduce_ms: 1897.21, paper_pct: 92.0 },
    Row { ethernet: true, gpus: 4, batch1: false, accum: 1, paper_allreduce_ms: 239.76, paper_pct: 58.0 },
    Row { ethernet: false, gpus: 64, batch1: true, accum: 1, paper_allreduce_ms: 316.18, paper_pct: 75.0 },
    Row { ethernet: false, gpus: 64, batch1: false, accum: 1, paper_allreduce_ms: 336.40, paper_pct: 69.0 },
    Row { ethernet: false, gpus: 64, batch1: false, accum: 4, paper_allreduce_ms: 339.52, paper_pct: 44.0 },
    Row { ethernet: false, gpus: 32, batch1: false, accum: 1, paper_allreduce_ms: 297.28, paper_pct: 67.0 },
    Row { ethernet: false, gpus: 16, batch1: false, accum: 1, paper_allreduce_ms: 183.74, paper_pct: 55.0 },
    Row { ethernet: false, gpus: 8, batch1: false, accum: 1, paper_allreduce_ms: 28.18, paper_pct: 16.0 },
];

fn model_row(r: &Row) -> (f64, f64) {
    let net = if r.ethernet {
        NetworkModel::ethernet()
    } else {
        NetworkModel::infiniband()
    };
    let compute = if r.batch1 {
        ComputeModel::bert_large_v100_b1()
    } else {
        ComputeModel::bert_large_v100()
    };
    let ar = fp16_allreduce_time(&net, r.gpus, BERT_LARGE);
    let pct = 100.0 * ar / (compute.step_compute(r.accum) + ar);
    (ar * 1e3, pct)
}

/// Every multi-node allreduce time within 45% of the paper's measurement
/// (the 2-node Ethernet row is the loosest; most rows land within 15%).
#[test]
#[cfg_attr(miri, ignore = "multi-rank fan-out is prohibitively slow under Miri")]
fn allreduce_times_within_tolerance() {
    for (i, r) in ROWS.iter().enumerate() {
        let (ms, _) = model_row(r);
        let rel = (ms - r.paper_allreduce_ms).abs() / r.paper_allreduce_ms;
        assert!(
            rel < 0.45,
            "row {i}: model {ms:.0} ms vs paper {} ms ({:.0}% off)",
            r.paper_allreduce_ms,
            rel * 100.0
        );
    }
}

/// allreduce%% within 12 percentage points on every row.
#[test]
#[cfg_attr(miri, ignore = "multi-rank fan-out is prohibitively slow under Miri")]
fn allreduce_percentages_within_tolerance() {
    for (i, r) in ROWS.iter().enumerate() {
        let (_, pct) = model_row(r);
        assert!(
            (pct - r.paper_pct).abs() < 12.0,
            "row {i}: model {pct:.0}%% vs paper {}%%",
            r.paper_pct
        );
    }
}

/// The two qualitative Table 1 takeaways the paper draws:
/// comm%% grows with node count and shrinks with gradient accumulation.
#[test]
#[cfg_attr(miri, ignore = "multi-rank fan-out is prohibitively slow under Miri")]
fn qualitative_trends() {
    let pct = |gpus: usize, accum: usize| {
        let net = NetworkModel::ethernet();
        let compute = ComputeModel::bert_large_v100();
        let ar = fp16_allreduce_time(&net, gpus, BERT_LARGE);
        100.0 * ar / (compute.step_compute(accum) + ar)
    };
    assert!(pct(64, 1) > pct(8, 1));
    assert!(pct(64, 4) < pct(64, 1));
    // Ethernet communicates proportionally more than InfiniBand
    let ib = {
        let net = NetworkModel::infiniband();
        let compute = ComputeModel::bert_large_v100();
        let ar = fp16_allreduce_time(&net, 64, BERT_LARGE);
        100.0 * ar / (compute.step_compute(1) + ar)
    };
    assert!(pct(64, 1) > ib + 20.0);
}
