//! Chaos/recovery bench: the compressed allreduce on an **adversarial
//! wire** — deterministic drop/corrupt/reorder injection repaired by the
//! NACK/retransmit layer — against the fault-free baseline, plus the
//! analytic degraded-network fig5/fig9 sweep at the paper's 64–256 rank
//! scale.
//!
//! Two claims are asserted right here so a regression fails the bench:
//!
//! * **bit-equality** — the chaos run's output must match the fault-free
//!   run exactly (recovery, not unwinding);
//! * **volume** — the *delivered* 1-bit wire volume, retransmission and
//!   control overhead included, stays ≤ 1/5 of the fp32 volume (§7.1),
//!   both measured at 8 ranks and modeled at 64–256 ranks under every
//!   degraded scenario.
//!
//!     cargo bench --bench chaos_transport
//!
//! Results land in the repo-root `BENCH_chaos.json`
//! (`OBADAM_BENCH_SMOKE=1` runs single-sample smoke passes in CI).

use onebit_adam::compress::CompressionKind;
use onebit_adam::netsim::collectives::{
    degraded_compressed_allreduce_time, degraded_compressed_step_gross_total,
    degraded_fp16_allreduce_time, degraded_plain_step_gross_total,
    DegradedScenario,
};
use onebit_adam::netsim::NetworkModel;
use onebit_adam::transport::{
    ChaosScenario, RecoveryStats, TcpOptions, TransportBackend,
    TransportCollective,
};
use onebit_adam::util::bench::{black_box, BenchJson, Bencher};
use onebit_adam::util::prng::Rng;

fn chaos_opts() -> TcpOptions {
    TcpOptions {
        attempt_timeout: std::time::Duration::from_millis(250),
        recv_timeout: std::time::Duration::from_secs(20),
        ..TcpOptions::default()
    }
}

/// One fresh single-step run under `scenario`, so the recovery ledger is
/// per-step rather than cumulative across bench iterations.
fn one_step(
    workers: usize,
    n: usize,
    kind: CompressionKind,
    scenario: &ChaosScenario,
    inputs: &[Vec<f32>],
    out: &mut [f32],
) -> (usize, RecoveryStats) {
    let mut car = TransportCollective::with_chaos(
        TransportBackend::InMemory,
        workers,
        n,
        kind,
        1,
        &chaos_opts(),
        scenario,
    )
    .expect("chaos transport mesh");
    car.allreduce(inputs, out);
    (car.last_stats().gross_total(), car.recovery_stats())
}

fn main() {
    let b = Bencher::from_env();
    let mut json = BenchJson::new_in("chaos_transport", "BENCH_chaos.json");

    // ---- measured: 8 ranks × 1M elements, lossy wire --------------------
    // The sleep-free lossy scenario (drop 5% / corrupt 2% / reorder 5%)
    // keeps the bench measuring recovery work, not injected sleeps.
    let workers = 8usize;
    let n = 1usize << 20;
    let scenario = ChaosScenario::lossy(0xC0FFEE);
    let base = Rng::new(23);
    let inputs: Vec<Vec<f32>> = (0..workers)
        .map(|i| base.fork(i as u64).normal_vec(n, 1.0))
        .collect();
    let mut out_clean = vec![0.0f32; n];
    let mut out_chaos = vec![0.0f32; n];

    let mut clean = TransportCollective::new(
        TransportBackend::InMemory,
        workers,
        n,
        CompressionKind::OneBit,
    )
    .expect("transport mesh");
    let r_clean = b.run(
        &format!("chaos_allreduce (fault-free/1bit) w={workers} n={n}"),
        || {
            black_box(clean.allreduce(&inputs, &mut out_clean));
        },
    );
    println!("{}", r_clean.report());
    json.push_with(
        &r_clean,
        &[(
            "measured_gross_bytes_total",
            clean.last_stats().gross_total() as f64,
        )],
    );

    let mut chaotic = TransportCollective::with_chaos(
        TransportBackend::InMemory,
        workers,
        n,
        CompressionKind::OneBit,
        1,
        &chaos_opts(),
        &scenario,
    )
    .expect("chaos transport mesh");
    let r_chaos = b.run(
        &format!("chaos_allreduce (lossy/1bit) w={workers} n={n}"),
        || {
            black_box(chaotic.allreduce(&inputs, &mut out_chaos));
        },
    );
    // Per-step wire accounting is identical on both sides (the timed
    // loops run auto-scaled — different — iteration counts, so outputs
    // are compared below on fixed-step fresh meshes instead).
    assert_eq!(clean.last_stats(), chaotic.last_stats());
    let rec = chaotic.recovery_stats();
    assert!(rec.injected_faults() > 0, "lossy scenario injected nothing");
    let slowdown = r_chaos.median_ns() / r_clean.median_ns();
    println!(
        "{}  => {:.2}x vs fault-free; {} faults injected \
         ({} drops / {} corruptions / {} reorders), {} retransmits served",
        r_chaos.report(),
        slowdown,
        rec.injected_faults(),
        rec.injected_drops,
        rec.injected_corruptions,
        rec.injected_reorders,
        rec.retransmits_served,
    );
    json.push_with(
        &r_chaos,
        &[
            ("slowdown_vs_fault_free", slowdown),
            ("injected_faults", rec.injected_faults() as f64),
            ("retransmits_served", rec.retransmits_served as f64),
            ("retransmit_bytes", rec.retransmit_bytes as f64),
            ("control_bytes", rec.control_bytes as f64),
            ("recovery_overhead_bytes", rec.overhead_bytes() as f64),
        ],
    );

    // Recovery, not unwinding: on fixed-step fresh meshes the lossy wire
    // must reproduce the fault-free bits exactly.
    {
        let mut c = TransportCollective::new(
            TransportBackend::InMemory,
            workers,
            n,
            CompressionKind::OneBit,
        )
        .expect("transport mesh");
        let mut x = TransportCollective::with_chaos(
            TransportBackend::InMemory,
            workers,
            n,
            CompressionKind::OneBit,
            1,
            &chaos_opts(),
            &scenario,
        )
        .expect("chaos transport mesh");
        for step in 0..2 {
            c.allreduce(&inputs, &mut out_clean);
            x.allreduce(&inputs, &mut out_chaos);
            assert_eq!(
                out_clean, out_chaos,
                "chaos run diverged from the fault-free run at step {step}"
            );
        }
    }

    // ---- measured volume: delivered 1-bit (recovery included) vs fp32 ---
    // Fresh single-step runs give a per-step ledger.
    let mut scratch = vec![0.0f32; n];
    let (bit_gross, bit_rec) = one_step(
        workers,
        n,
        CompressionKind::OneBit,
        &scenario,
        &inputs,
        &mut scratch,
    );
    let (fp32_gross, fp32_rec) = one_step(
        workers,
        n,
        CompressionKind::None,
        &scenario,
        &inputs,
        &mut scratch,
    );
    let bit_delivered = bit_gross as f64 + bit_rec.overhead_bytes() as f64;
    let fp32_delivered = fp32_gross as f64 + fp32_rec.overhead_bytes() as f64;
    let reduction = fp32_delivered / bit_delivered;
    let reduction_vs_clean_fp32 = fp32_gross as f64 / bit_delivered;
    assert!(
        reduction >= 5.0 && reduction_vs_clean_fp32 >= 5.0,
        "delivered 1-bit volume (recovery included) not ≤ 1/5 of fp32: \
         {reduction:.2}x vs lossy fp32, {reduction_vs_clean_fp32:.2}x vs \
         clean fp32"
    );
    println!(
        "delivered volume on the lossy wire: 1-bit {} B (+{} B recovery) \
         vs fp32 {} B => {reduction:.2}x reduction",
        bit_gross,
        bit_rec.overhead_bytes(),
        fp32_gross,
    );
    let r_vol = b.run("chaos_volume_ledger (lossy) single-step", || {
        black_box(bit_delivered);
    });
    json.push_with(
        &r_vol,
        &[
            ("bit_gross_bytes", bit_gross as f64),
            ("bit_recovery_overhead_bytes", bit_rec.overhead_bytes() as f64),
            ("fp32_gross_bytes", fp32_gross as f64),
            ("volume_reduction_delivered", reduction),
            ("volume_reduction_vs_clean_fp32", reduction_vs_clean_fp32),
        ],
    );

    // ---- analytic: degraded fig5/fig9 sweep at 64–256 ranks -------------
    let net = NetworkModel::ethernet();
    let d = 340_000_000usize; // BERT-large step payload (elements)
    for n_gpus in [64usize, 128, 256] {
        for s in DegradedScenario::paper_sweep() {
            let comp =
                degraded_compressed_allreduce_time(&net, &s, n_gpus, d);
            let full = degraded_fp16_allreduce_time(&net, &s, n_gpus, d);
            let bit = degraded_compressed_step_gross_total(
                CompressionKind::OneBit,
                n_gpus,
                d,
                &s,
            );
            let fp32 = degraded_plain_step_gross_total(n_gpus, d, &s);
            assert!(
                fp32 / bit >= 5.0,
                "degraded volume claim broken at n={n_gpus} {}",
                s.name
            );
            assert!(
                comp < full,
                "1-bit slower than fp16 at n={n_gpus} {}",
                s.name
            );
            let r = b.run(
                &format!(
                    "degraded_model ({}) n={n_gpus} ethernet bert-large",
                    s.name
                ),
                || {
                    black_box(degraded_compressed_allreduce_time(
                        &net, &s, n_gpus, d,
                    ));
                },
            );
            println!(
                "{}  => modeled {:.3} s vs fp16 {:.3} s ({:.1}x), \
                 delivered volume ratio {:.1}x",
                r.report(),
                comp,
                full,
                full / comp,
                fp32 / bit,
            );
            json.push_with(
                &r,
                &[
                    ("modeled_compressed_s", comp),
                    ("modeled_fp16_s", full),
                    ("modeled_speedup", full / comp),
                    ("volume_inflation", s.volume_inflation()),
                    ("delivered_volume_reduction", fp32 / bit),
                ],
            );
        }
    }

    json.flush();
}
