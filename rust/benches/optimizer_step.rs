//! Bench: full optimizer steps (native math backend) — Adam warmup step vs
//! 1-bit compression step — the L3 per-step CPU budget.  Also times the
//! PJRT (L1 Pallas artifact) path when `artifacts/` is present, giving the
//! native-vs-PJRT dispatch overhead the ExecMode choice is based on.
//!
//!     cargo bench --bench optimizer_step

use onebit_adam::optim::onebit_adam::{OneBitAdam, OneBitAdamConfig};
use onebit_adam::optim::{Adam, DistOptimizer};
use onebit_adam::runtime::Runtime;
use onebit_adam::util::bench::{black_box, Bencher};
use onebit_adam::util::prng::Rng;

fn main() {
    let b = Bencher::default();
    let workers = 4;
    for n in [65_536usize, 1 << 20] {
        let base = Rng::new(3);
        let grads: Vec<Vec<f32>> = (0..workers)
            .map(|i| base.fork(i as u64).normal_vec(n, 1.0))
            .collect();

        let mut adam = Adam::new(workers, vec![0.1; n]);
        let r = b.run(&format!("adam_step (native) n={n}"), || {
            black_box(adam.step(&grads, 1e-4));
        });
        println!("{}", r.report());

        let mut onebit = OneBitAdam::new(
            workers,
            vec![0.1; n],
            OneBitAdamConfig { warmup_steps: Some(0), ..Default::default() },
        );
        onebit.step(&grads, 1e-4); // enter compression phase
        let r = b.run(&format!("onebit_step (native) n={n}"), || {
            black_box(onebit.step(&grads, 1e-4));
        });
        println!(
            "{}  => {:.2} GB/s over {workers} momenta",
            r.report(),
            r.throughput((n * workers) as f64 * 4.0) / 1e9
        );
    }

    // PJRT path (L1 Pallas artifacts) if available
    if let Ok(rt) = Runtime::load("artifacts") {
        let n = 65_536usize;
        if rt.has(&format!("adam_step_{n}")) {
            let mut rng = Rng::new(5);
            let p = rng.normal_vec(n, 1.0);
            let m = vec![0.0f32; n];
            let v = vec![0.0f32; n];
            let g = rng.normal_vec(n, 1.0);
            let r = b.run(&format!("adam_step (pjrt) n={n}"), || {
                black_box(rt.adam_step(n, &p, &m, &v, &g, 1e-4).unwrap());
            });
            println!("{}", r.report());
            let err = vec![0.0f32; n];
            let r = b.run(&format!("onebit_compress (pjrt) n={n}"), || {
                black_box(rt.onebit_compress(n, &g, &err).unwrap());
            });
            println!("{}", r.report());
        }
    } else {
        println!("(artifacts/ missing — PJRT path skipped)");
    }
}
