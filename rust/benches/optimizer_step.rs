//! Bench: full optimizer steps (native math backend) — Adam warmup step vs
//! 1-bit compression step — the L3 per-step CPU budget.  The 1-bit step is
//! timed on both allreduce engines (fused bit-domain vs the pre-change
//! decode-average reference); the warmup-phase step is timed on both the
//! fused tree-reduce path and the pre-change scalar path
//! (`ScalarBackend` + `PlainPath::Reference`), with the per-phase numbers
//! split across `BENCH_step.json` (compression) and `BENCH_warmup.json`
//! (warmup) so the perf trajectory distinguishes the two throughputs.
//! Also times the PJRT (L1 Pallas artifact) path when `artifacts/` is
//! present, giving the native-vs-PJRT dispatch overhead the ExecMode
//! choice is based on.
//!
//!     cargo bench --bench optimizer_step

use onebit_adam::comm::{AllreducePath, PlainPath};
use onebit_adam::compress::CompressionKind;
use onebit_adam::netsim::collectives::{
    onebit_adam_run_payload_per_gpu, zeroone_adam_run_payload_per_gpu,
};
use onebit_adam::optim::backend::ScalarBackend;
use onebit_adam::optim::onebit_adam::{OneBitAdam, OneBitAdamConfig};
use onebit_adam::optim::zeroone_adam::{ZeroOneAdam, ZeroOneAdamConfig};
use onebit_adam::optim::{Adam, DistOptimizer};
use onebit_adam::runtime::Runtime;
use onebit_adam::util::bench::{black_box, smoke_mode, BenchJson, Bencher};
use onebit_adam::util::prng::Rng;

/// Warmup-phase steps: fused tree-reduce path vs the pre-change scalar
/// path, 8 workers on a 1M-element tensor (smoke mode shrinks the
/// tensor).
fn warmup_phase(b: &Bencher) {
    let mut json =
        BenchJson::new_in("optimizer_step_warmup", "BENCH_warmup.json");
    let workers = 8usize;
    let n: usize = if smoke_mode() { 1 << 16 } else { 1 << 20 };
    let base = Rng::new(13);
    let grads: Vec<Vec<f32>> = (0..workers)
        .map(|i| base.fork(i as u64).normal_vec(n, 1.0))
        .collect();
    // warmup_steps = usize::MAX pins the optimizer in the warmup phase.
    let cfg = OneBitAdamConfig {
        warmup_steps: Some(usize::MAX),
        ..Default::default()
    };

    let mut fast = OneBitAdam::new(workers, vec![0.1; n], cfg.clone());
    let r_fast = b.run(
        &format!("warmup_step (tree-reduce + fused) w={workers} n={n}"),
        || {
            black_box(fast.step(&grads, 1e-4));
        },
    );
    println!(
        "{}  => {:.2} GB/s over {workers} grads",
        r_fast.report(),
        r_fast.throughput((n * workers) as f64 * 4.0) / 1e9
    );

    let mut slow = OneBitAdam::with_backend(
        workers,
        vec![0.1; n],
        cfg,
        Box::new(ScalarBackend),
    );
    slow.set_plain_path(PlainPath::Reference);
    let r_slow = b.run(
        &format!("warmup_step (scalar reference) w={workers} n={n}"),
        || {
            black_box(slow.step(&grads, 1e-4));
        },
    );
    println!("{}", r_slow.report());

    let speedup = r_slow.median_ns() / r_fast.median_ns();
    println!("  warmup-phase speedup vs scalar reference: {speedup:.2}x");
    json.push(&r_slow);
    json.push_with(&r_fast, &[("speedup_vs_scalar_reference", speedup)]);
    json.flush();
}

/// 0/1 Adam section (`BENCH_zeroone.json`): steady-state step cost next
/// to 1-bit Adam's compression step, plus the run-level **measured**
/// wire volume of both optimizers over the same horizon — reconciled
/// exactly against the `netsim::collectives` run model and asserted
/// strictly smaller for 0/1 Adam (the warmup fp32 term is gone).
fn zeroone_phase(b: &Bencher) {
    let mut json =
        BenchJson::new_in("optimizer_step_zeroone", "BENCH_zeroone.json");
    let workers = 8usize;
    let n: usize = if smoke_mode() { 1 << 16 } else { 1 << 20 };
    let steps: usize = if smoke_mode() { 40 } else { 100 };
    let base = Rng::new(17);
    let grads: Vec<Vec<f32>> = (0..workers)
        .map(|i| base.fork(i as u64).normal_vec(n, 1.0))
        .collect();

    let mut zo = ZeroOneAdam::new(
        workers,
        vec![0.1; n],
        ZeroOneAdamConfig::default(),
    );
    // skip the dense early syncs so the timed steps are dominated by
    // the steady-state 1-bit path (rare sync steps still land in the
    // sample set; the median absorbs them)
    for _ in 0..5 {
        zo.step(&grads, 1e-4);
    }
    let r_zo = b.run(&format!("zeroone_step (native) n={n}"), || {
        black_box(zo.step(&grads, 1e-4));
    });
    println!(
        "{}  => {:.2} GB/s over {workers} momenta",
        r_zo.report(),
        r_zo.throughput((n * workers) as f64 * 4.0) / 1e9
    );

    let mut ob = OneBitAdam::new(
        workers,
        vec![0.1; n],
        OneBitAdamConfig { warmup_steps: Some(0), ..Default::default() },
    );
    ob.step(&grads, 1e-4); // enter compression phase
    let r_ob =
        b.run(&format!("onebit_compression_step (native) n={n}"), || {
            black_box(ob.step(&grads, 1e-4));
        });
    println!("{}", r_ob.report());

    // Run-level measured volume on fresh optimizers: 0/1 Adam from step
    // 0 vs 1-bit Adam with its default warmup fraction (steps/5).
    let warmup = steps / 5;
    let mut zo = ZeroOneAdam::new(
        workers,
        vec![0.1; n],
        ZeroOneAdamConfig::default(),
    );
    let mut ob = OneBitAdam::new(
        workers,
        vec![0.1; n],
        OneBitAdamConfig {
            warmup_steps: Some(warmup),
            ..Default::default()
        },
    );
    let (mut zo_bytes, mut ob_bytes) = (0usize, 0usize);
    for _ in 0..steps {
        zo_bytes += zo.step(&grads, 1e-4).comm.total_per_gpu();
        ob_bytes += ob.step(&grads, 1e-4).comm.total_per_gpu();
    }
    let kind = CompressionKind::OneBit;
    assert_eq!(
        zo_bytes,
        zeroone_adam_run_payload_per_gpu(kind, workers, n, steps, 1),
        "0/1 Adam measured volume disagrees with the netsim run model"
    );
    assert_eq!(
        ob_bytes,
        onebit_adam_run_payload_per_gpu(kind, workers, n, warmup, steps),
        "1-bit Adam measured volume disagrees with the netsim run model"
    );
    assert!(
        zo_bytes < ob_bytes,
        "0/1 Adam must move strictly fewer bytes: {zo_bytes} vs {ob_bytes}"
    );
    let reduction = ob_bytes as f64 / zo_bytes as f64;
    println!(
        "  run volume over {steps} steps: zeroone {:.2} MB/gpu vs onebit \
         {:.2} MB/gpu => {reduction:.2}x reduction (model agrees exactly)",
        zo_bytes as f64 / 1e6,
        ob_bytes as f64 / 1e6
    );
    json.push(&r_ob);
    json.push_with(
        &r_zo,
        &[
            ("measured_run_payload_bytes_per_gpu", zo_bytes as f64),
            ("onebit_run_payload_bytes_per_gpu", ob_bytes as f64),
            ("volume_reduction_vs_onebit_adam", reduction),
        ],
    );
    json.flush();
}

fn main() {
    let b = Bencher::from_env();
    warmup_phase(&b);
    zeroone_phase(&b);
    let mut json = BenchJson::new("optimizer_step");
    let workers = 4;
    let sizes: &[usize] =
        if smoke_mode() { &[65_536] } else { &[65_536, 1 << 20] };
    for &n in sizes {
        let base = Rng::new(3);
        let grads: Vec<Vec<f32>> = (0..workers)
            .map(|i| base.fork(i as u64).normal_vec(n, 1.0))
            .collect();

        let mut adam = Adam::new(workers, vec![0.1; n]);
        let r = b.run(&format!("adam_step (native) n={n}"), || {
            black_box(adam.step(&grads, 1e-4));
        });
        println!("{}", r.report());
        json.push(&r);

        // 1-bit step on the fused bit-domain engine (the default).
        let mut onebit = OneBitAdam::new(
            workers,
            vec![0.1; n],
            OneBitAdamConfig { warmup_steps: Some(0), ..Default::default() },
        );
        onebit.step(&grads, 1e-4); // enter compression phase
        let r_bit = b.run(&format!("onebit_step (native) n={n}"), || {
            black_box(onebit.step(&grads, 1e-4));
        });
        println!(
            "{}  => {:.2} GB/s over {workers} momenta",
            r_bit.report(),
            r_bit.throughput((n * workers) as f64 * 4.0) / 1e9
        );

        // Same step on the pre-change decode-average reference engine.
        let mut onebit_ref = OneBitAdam::new(
            workers,
            vec![0.1; n],
            OneBitAdamConfig { warmup_steps: Some(0), ..Default::default() },
        );
        onebit_ref.set_allreduce_path(AllreducePath::DecodeAverage);
        onebit_ref.step(&grads, 1e-4); // enter compression phase
        let r_ref =
            b.run(&format!("onebit_step (decode-average) n={n}"), || {
                black_box(onebit_ref.step(&grads, 1e-4));
            });
        println!("{}", r_ref.report());
        json.push(&r_ref);

        let speedup = r_ref.median_ns() / r_bit.median_ns();
        println!("  bit-domain speedup vs decode-average: {speedup:.2}x");
        json.push_with(&r_bit, &[("speedup_vs_decode_average", speedup)]);
    }

    // PJRT path (L1 Pallas artifacts) if available
    if let Ok(rt) = Runtime::load("artifacts") {
        let n = 65_536usize;
        if rt.has(&format!("adam_step_{n}")) {
            let mut rng = Rng::new(5);
            let p = rng.normal_vec(n, 1.0);
            let m = vec![0.0f32; n];
            let v = vec![0.0f32; n];
            let g = rng.normal_vec(n, 1.0);
            let r = b.run(&format!("adam_step (pjrt) n={n}"), || {
                black_box(rt.adam_step(n, &p, &m, &v, &g, 1e-4).unwrap());
            });
            println!("{}", r.report());
            json.push(&r);
            let err = vec![0.0f32; n];
            let r = b.run(&format!("onebit_compress (pjrt) n={n}"), || {
                black_box(rt.onebit_compress(n, &g, &err).unwrap());
            });
            println!("{}", r.report());
            json.push(&r);
        }
    } else {
        println!("(artifacts/ missing — PJRT path skipped)");
    }

    json.flush();
}
