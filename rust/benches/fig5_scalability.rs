//! Bench/repro: Figure 5 (a)(b)(c) — warmup vs compression-stage
//! throughput scaling on the Ethernet and InfiniBand clusters, plus the
//! Figure 4(b)/Figure 7 end-to-end time projections.
//!
//!     cargo bench --bench fig5_scalability

use onebit_adam::repro::timing::{fig4b, fig5, fig7, Fig5Variant};

fn main() {
    fig5(Fig5Variant::A).expect("fig5a");
    fig5(Fig5Variant::B).expect("fig5b");
    fig5(Fig5Variant::C).expect("fig5c");
    fig4b().expect("fig4b");
    fig7().expect("fig7");
}
