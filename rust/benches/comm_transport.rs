//! Transport data-plane bench: the compressed allreduce running over
//! **real wire backends** — framed messages through in-memory queues vs
//! loopback TCP sockets — at the acceptance point of 8 ranks × 1M
//! elements, fp32 vs 1-bit payloads.
//!
//! Beyond throughput, this bench is the volume ledger the paper's §7.1
//! claim is checked against in *measured bytes*: each configuration
//! records its per-GPU payload volume, gross wire bytes (frame headers
//! included), and the netsim model's prediction
//! (`netsim::collectives::calibrate` must agree exactly), and the 1-bit
//! rows carry `volume_reduction_vs_fp32` — asserted ≥ 5× right here so a
//! regression fails the bench, not just a dashboard.
//!
//!     cargo bench --bench comm_transport
//!
//! Results land in the repo-root `BENCH_transport.json`
//! (`OBADAM_BENCH_SMOKE=1` runs single-sample smoke passes in CI).

use onebit_adam::compress::CompressionKind;
use onebit_adam::netsim::collectives::calibrate;
use onebit_adam::transport::{
    TransportBackend, TransportCollective, TransportStats,
};
use onebit_adam::util::bench::{black_box, BenchJson, Bencher};
use onebit_adam::util::prng::Rng;

fn kind_name(kind: CompressionKind) -> &'static str {
    match kind {
        CompressionKind::None => "fp32",
        CompressionKind::OneBit => "1bit",
        CompressionKind::NBit(_) => "nbit",
    }
}

fn backend_name(b: TransportBackend) -> &'static str {
    match b {
        TransportBackend::InMemory => "in-memory",
        TransportBackend::Tcp => "tcp",
    }
}

fn main() {
    let b = Bencher::from_env();
    let mut json =
        BenchJson::new_in("comm_transport", "BENCH_transport.json");

    // The acceptance configuration: 8 ranks × 1M elements (kept in smoke
    // mode — the volume ledger must exist on every CI run).
    let workers = 8usize;
    let n = 1usize << 20;
    let base = Rng::new(23);
    let inputs: Vec<Vec<f32>> = (0..workers)
        .map(|i| base.fork(i as u64).normal_vec(n, 1.0))
        .collect();
    let mut out = vec![0.0f32; n];

    for backend in [TransportBackend::InMemory, TransportBackend::Tcp] {
        let mut fp32_stats: Option<TransportStats> = None;
        for kind in [CompressionKind::None, CompressionKind::OneBit] {
            let mut car =
                TransportCollective::new(backend, workers, n, kind)
                    .expect("transport mesh");
            let r = b.run(
                &format!(
                    "transport_allreduce ({}/{}) w={workers} n={n}",
                    backend_name(backend),
                    kind_name(kind)
                ),
                || {
                    black_box(car.allreduce(&inputs, &mut out));
                },
            );
            let ts = car.last_stats();
            let cal = calibrate(kind, workers, n, &ts);
            assert!(
                cal.agrees(),
                "netsim volume model disagrees with measured bytes: {cal:?}"
            );
            println!(
                "{}  => {:.2} GB/s of input tensors",
                r.report(),
                r.throughput((n * workers) as f64 * 4.0) / 1e9
            );
            println!(
                "  measured: {} payload B/gpu, {} gross B total \
                 ({} frames, {} B header overhead; model agrees exactly)",
                ts.comm.total_per_gpu(),
                ts.gross_total(),
                ts.frames_sent,
                cal.header_overhead_bytes()
            );
            let mut extras = vec![
                (
                    "measured_payload_bytes_per_gpu",
                    ts.comm.total_per_gpu() as f64,
                ),
                ("measured_gross_bytes_total", ts.gross_total() as f64),
                (
                    "netsim_predicted_payload_bytes_per_gpu",
                    cal.predicted_payload_per_gpu as f64,
                ),
                (
                    "header_overhead_bytes",
                    cal.header_overhead_bytes() as f64,
                ),
                ("frames_sent", ts.frames_sent as f64),
            ];
            if let Some(fp) = &fp32_stats {
                // the §7.1 acceptance: 1-bit wire volume ≤ 1/5 of fp32
                let gross_red =
                    fp.gross_total() as f64 / ts.gross_total() as f64;
                let payload_red = fp.comm.total_per_gpu() as f64
                    / ts.comm.total_per_gpu() as f64;
                assert!(
                    gross_red >= 5.0 && payload_red >= 5.0,
                    "1-bit wire volume not ≤ 1/5 of fp32: gross \
                     {gross_red:.2}x, payload {payload_red:.2}x"
                );
                println!(
                    "  volume reduction vs fp32: {payload_red:.2}x \
                     payload, {gross_red:.2}x gross"
                );
                extras.push(("volume_reduction_vs_fp32", payload_red));
                extras.push(("gross_volume_reduction_vs_fp32", gross_red));
            } else {
                fp32_stats = Some(ts);
            }
            json.push_with(&r, &extras);
        }
    }

    // Warmup-phase average over the wire (both backends), for the full
    // two-phase wall-clock picture.
    for backend in [TransportBackend::InMemory, TransportBackend::Tcp] {
        let mut car = TransportCollective::new(
            backend,
            workers,
            n,
            CompressionKind::None,
        )
        .expect("transport mesh");
        let r = b.run(
            &format!(
                "transport_plain_average ({}) w={workers} n={n}",
                backend_name(backend)
            ),
            || {
                black_box(car.plain_average(&inputs, &mut out));
            },
        );
        println!("{}", r.report());
        let ts = car.last_stats();
        json.push_with(
            &r,
            &[("measured_gross_bytes_total", ts.gross_total() as f64)],
        );
    }

    json.flush();
}
