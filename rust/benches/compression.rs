//! Micro-bench: the L3 hot path — error-compensated 1-bit compression and
//! sign packing — across tensor sizes.  This is the per-step CPU cost the
//! compressed_allreduce adds on top of the wire transfer.
//!
//!     cargo bench --bench compression

use onebit_adam::compress::onebit::onebit_compress_ec;
use onebit_adam::compress::pack::{pack_signs, unpack_signs_scaled, wire_size};
use onebit_adam::util::bench::{black_box, Bencher};
use onebit_adam::util::prng::Rng;

fn main() {
    let b = Bencher::default();
    println!("== error-compensated 1-bit compression (fused quantize) ==");
    for n in [65_536usize, 1 << 20, 1 << 23] {
        let mut rng = Rng::new(1);
        let val = rng.normal_vec(n, 1.0);
        let mut err = vec![0.0f32; n];
        let mut scratch = vec![0.0f32; n];
        let mut out = vec![0.0f32; n];
        let r = b.run(&format!("onebit_compress_ec n={n}"), || {
            black_box(onebit_compress_ec(&val, &mut err, &mut scratch, &mut out));
        });
        println!(
            "{}  => {:.2} GB/s effective",
            r.report(),
            r.throughput(n as f64 * 4.0) / 1e9
        );
    }

    println!("\n== sign packing / unpacking (the wire format) ==");
    for n in [1 << 20, 1 << 23] {
        let mut rng = Rng::new(2);
        let q = rng.normal_vec(n, 1.0);
        let r = b.run(&format!("pack_signs n={n}"), || {
            black_box(pack_signs(&q));
        });
        println!(
            "{}  => {:.2} Gelem/s",
            r.report(),
            r.throughput(n as f64) / 1e9
        );
        let words = pack_signs(&q);
        let mut out = vec![0.0f32; n];
        let r = b.run(&format!("unpack_signs n={n}"), || {
            unpack_signs_scaled(&words, 0.5, &mut out);
            black_box(&out);
        });
        println!(
            "{}  => {:.2} Gelem/s",
            r.report(),
            r.throughput(n as f64) / 1e9
        );
        println!(
            "  wire: {} B for {} elements ({:.1}x smaller than fp32)",
            wire_size(n),
            n,
            (n * 4) as f64 / wire_size(n) as f64
        );
    }
}
