//! Micro-bench: the L3 hot path — error-compensated 1-bit compression and
//! sign packing — across tensor sizes.  This is the per-step CPU cost the
//! compressed_allreduce adds on top of the wire transfer.  Benches both
//! the two-pass compress (dequantized f32 output) and the fused
//! compress-to-wire path (`onebit_compress_ec_packed`), plus the
//! bit-domain vote-average kernel.
//!
//!     cargo bench --bench compression

use onebit_adam::compress::onebit::{
    onebit_compress_ec, onebit_compress_ec_packed,
};
use onebit_adam::compress::pack::{
    pack_signs, pack_signs_into, quantize_pack_ec, unpack_signs_scaled,
    vote_average_strided, wire_size,
};
use onebit_adam::util::bench::{black_box, smoke_mode, BenchJson, Bencher};
use onebit_adam::util::prng::Rng;

fn main() {
    let b = Bencher::from_env();
    let mut json = BenchJson::new("compression");
    println!("== error-compensated 1-bit compression ==");
    let sizes: &[usize] = if smoke_mode() {
        &[65_536]
    } else {
        &[65_536, 1 << 20, 1 << 23]
    };
    for &n in sizes {
        let mut rng = Rng::new(1);
        let val = rng.normal_vec(n, 1.0);
        let mut err = vec![0.0f32; n];
        let mut scratch = vec![0.0f32; n];
        let mut out = vec![0.0f32; n];
        let r = b.run(&format!("onebit_compress_ec n={n}"), || {
            black_box(onebit_compress_ec(
                &val,
                &mut err,
                &mut scratch,
                &mut out,
            ));
        });
        println!(
            "{}  => {:.2} GB/s effective",
            r.report(),
            r.throughput(n as f64 * 4.0) / 1e9
        );
        json.push(&r);

        // Fused straight-to-wire variant: no dequantized tensor, no
        // scratch — compensate + quantize+pack in two passes over err.
        let mut err2 = vec![0.0f32; n];
        let mut words = vec![0u32; n.div_ceil(32)];
        let r = b.run(&format!("onebit_compress_ec_packed n={n}"), || {
            black_box(onebit_compress_ec_packed(&val, &mut err2, &mut words));
        });
        println!(
            "{}  => {:.2} GB/s effective",
            r.report(),
            r.throughput(n as f64 * 4.0) / 1e9
        );
        json.push(&r);
    }

    println!("\n== sign packing / unpacking (the wire format) ==");
    let sizes: &[usize] =
        if smoke_mode() { &[1 << 20] } else { &[1 << 20, 1 << 23] };
    for &n in sizes {
        let mut rng = Rng::new(2);
        let q = rng.normal_vec(n, 1.0);
        let r = b.run(&format!("pack_signs n={n}"), || {
            black_box(pack_signs(&q));
        });
        println!(
            "{}  => {:.2} Gelem/s",
            r.report(),
            r.throughput(n as f64) / 1e9
        );
        json.push(&r);
        // Fused quantize + pack + error feedback (pass 2 of the
        // straight-to-wire compress).  The compensated values stay
        // bounded (±scale oscillation), so every call does identical
        // work.
        let mut comp = rng.normal_vec(n, 1.0);
        let mut qwords = vec![0u32; n.div_ceil(32)];
        let r = b.run(&format!("quantize_pack_ec n={n}"), || {
            quantize_pack_ec(&mut comp, 0.8, &mut qwords);
            black_box(&qwords);
        });
        println!(
            "{}  => {:.2} Gelem/s",
            r.report(),
            r.throughput(n as f64) / 1e9
        );
        json.push(&r);

        let words = pack_signs(&q);
        let mut out = vec![0.0f32; n];
        let r = b.run(&format!("unpack_signs n={n}"), || {
            unpack_signs_scaled(&words, 0.5, &mut out);
            black_box(&out);
        });
        println!(
            "{}  => {:.2} Gelem/s",
            r.report(),
            r.throughput(n as f64) / 1e9
        );
        json.push(&r);

        // Bit-domain average kernel: 4 workers' sign words -> mean f32.
        let workers = 4usize;
        let wlen = n.div_ceil(32);
        let mut arena = vec![0u32; workers * wlen];
        for i in 0..workers {
            let vi: Vec<f32> =
                q.iter().map(|&x| x - i as f32 * 0.25).collect();
            pack_signs_into(&vi, &mut arena[i * wlen..(i + 1) * wlen]);
        }
        let scales = [0.9f32, 1.1, 1.0, 0.95];
        let mut acc = vec![0.0f32; n];
        let r = b.run(&format!("vote_average_strided w=4 n={n}"), || {
            vote_average_strided(&arena, wlen, 0, &scales, 0.25, &mut acc);
            black_box(&acc);
        });
        println!(
            "{}  => {:.2} Gelem/s aggregated",
            r.report(),
            r.throughput((n * workers) as f64) / 1e9
        );
        json.push(&r);

        println!(
            "  wire: {} B for {} elements ({:.1}x smaller than fp32)",
            wire_size(n),
            n,
            (n * 4) as f64 / wire_size(n) as f64
        );
    }

    json.flush();
}
