//! End-to-end bench of the collectives' *data-plane* cost: the full
//! compressed_allreduce (compress → chunk → pack → average → recompress →
//! gather) vs the plain fp32 average, on realistic tensor sizes.
//!
//!     cargo bench --bench comm_primitives

use onebit_adam::comm::plain::allreduce_average;
use onebit_adam::comm::CompressedAllreduce;
use onebit_adam::compress::CompressionKind;
use onebit_adam::util::bench::{black_box, Bencher};
use onebit_adam::util::prng::Rng;

fn main() {
    let b = Bencher::default();
    for workers in [4usize, 8, 16] {
        for n in [1 << 18, 1 << 21] {
            let base = Rng::new(7);
            let inputs: Vec<Vec<f32>> = (0..workers)
                .map(|i| base.fork(i as u64).normal_vec(n, 1.0))
                .collect();
            let mut out = vec![0.0f32; n];

            let r = b.run(
                &format!("plain_average w={workers} n={n}"),
                || {
                    black_box(allreduce_average(&inputs, &mut out));
                },
            );
            println!("{}", r.report());

            let mut car =
                CompressedAllreduce::new(workers, n, CompressionKind::OneBit);
            let r = b.run(
                &format!("compressed_allreduce w={workers} n={n}"),
                || {
                    black_box(car.allreduce(&inputs, &mut out));
                },
            );
            println!(
                "{}  => {:.2} GB/s of input tensors",
                r.report(),
                r.throughput((n * workers) as f64 * 4.0) / 1e9
            );
        }
    }
}
