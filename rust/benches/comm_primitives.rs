//! End-to-end bench of the collectives' *data-plane* cost: the full
//! compressed_allreduce (compress → chunk → pack → average → recompress →
//! gather) vs the plain fp32 average, on realistic tensor sizes.  The
//! compressed collective is timed on three configurations — fused
//! bit-domain (threaded, the default), bit-domain pinned to one thread,
//! and the pre-change decode-average reference — so both the fusion and
//! the thread-scaling win land in `BENCH_step.json`.  The plain fp32
//! average is timed on both `PlainPath` engines (tree-reduce vs the
//! scalar reference); those warmup-phase numbers go to
//! `BENCH_warmup.json`.
//!
//!     cargo bench --bench comm_primitives

use onebit_adam::comm::plain::{
    allreduce_average, allreduce_average_path, PlainPath,
};
use onebit_adam::comm::{
    AllreducePath, CompressedAllreduce, HierarchicalAllreduce,
};
use onebit_adam::compress::CompressionKind;
use onebit_adam::util::bench::{black_box, smoke_mode, BenchJson, Bencher};
use onebit_adam::util::par::default_threads;
use onebit_adam::util::prng::Rng;

fn main() {
    let b = Bencher::from_env();
    let mut json = BenchJson::new("comm_primitives");
    let mut warm_json =
        BenchJson::new_in("comm_plain_average", "BENCH_warmup.json");
    let worker_counts: &[usize] =
        if smoke_mode() { &[4] } else { &[4, 8, 16] };
    let sizes: &[usize] =
        if smoke_mode() { &[1 << 18] } else { &[1 << 18, 1 << 21] };
    for &workers in worker_counts {
        for &n in sizes {
            let base = Rng::new(7);
            let inputs: Vec<Vec<f32>> = (0..workers)
                .map(|i| base.fork(i as u64).normal_vec(n, 1.0))
                .collect();
            let mut out = vec![0.0f32; n];

            let r = b.run(
                &format!("plain_average w={workers} n={n}"),
                || {
                    black_box(allreduce_average(&inputs, &mut out));
                },
            );
            println!("{}", r.report());
            json.push(&r);

            // Warmup-phase engines: scalar reference vs tree-reduce.
            let r_plain_ref = b.run(
                &format!("plain_average (reference) w={workers} n={n}"),
                || {
                    black_box(allreduce_average_path(
                        PlainPath::Reference,
                        &inputs,
                        &mut out,
                        1,
                    ));
                },
            );
            println!("{}", r_plain_ref.report());
            let plain_speedup = r_plain_ref.median_ns() / r.median_ns();
            println!(
                "  tree-reduce speedup vs scalar reference: \
                 {plain_speedup:.2}x"
            );
            warm_json.push(&r_plain_ref);
            warm_json.push_with(
                &r,
                &[("speedup_vs_scalar_reference", plain_speedup)],
            );

            let mut car =
                CompressedAllreduce::new(workers, n, CompressionKind::OneBit);
            let r_bit = b.run(
                &format!(
                    "compressed_allreduce (bit-domain) w={workers} n={n}"
                ),
                || {
                    black_box(car.allreduce(&inputs, &mut out));
                },
            );
            println!(
                "{}  => {:.2} GB/s of input tensors",
                r_bit.report(),
                r_bit.throughput((n * workers) as f64 * 4.0) / 1e9
            );

            let mut car1 = CompressedAllreduce::with_options(
                workers,
                n,
                CompressionKind::OneBit,
                AllreducePath::BitDomain,
                1,
            );
            let r_bit1 = b.run(
                &format!(
                    "compressed_allreduce (bit-domain, 1 thread) \
                     w={workers} n={n}"
                ),
                || {
                    black_box(car1.allreduce(&inputs, &mut out));
                },
            );
            println!("{}", r_bit1.report());

            let mut car_ref = CompressedAllreduce::with_options(
                workers,
                n,
                CompressionKind::OneBit,
                AllreducePath::DecodeAverage,
                1,
            );
            let r_ref = b.run(
                &format!(
                    "compressed_allreduce (decode-average) w={workers} n={n}"
                ),
                || {
                    black_box(car_ref.allreduce(&inputs, &mut out));
                },
            );
            println!("{}", r_ref.report());
            json.push(&r_ref);

            let speedup_1t = r_ref.median_ns() / r_bit1.median_ns();
            let speedup = r_ref.median_ns() / r_bit.median_ns();
            println!(
                "  bit-domain speedup vs decode-average: {speedup_1t:.2}x \
                 single-thread, {speedup:.2}x threaded"
            );
            json.push_with(
                &r_bit1,
                &[("speedup_vs_decode_average", speedup_1t)],
            );
            json.push_with(
                &r_bit,
                &[("speedup_vs_decode_average", speedup)],
            );
        }
    }
    json.flush();
    warm_json.flush();

    // ---- Hierarchical topology: the BENCH_hierarchy.json acceptance
    // point is fixed at 8 workers × 1M elements (also in smoke mode — a
    // single sample there is cheap), flat vs group sizes {2, 4} vs the
    // chunk-streamed leader engine, each with `speedup_vs_flat`.
    let mut hier_json = BenchJson::new_in("comm_hierarchy", "BENCH_hierarchy.json");
    let workers = 8usize;
    let n = 1 << 20;
    let base = Rng::new(11);
    let inputs: Vec<Vec<f32>> = (0..workers)
        .map(|i| base.fork(i as u64).normal_vec(n, 1.0))
        .collect();
    let mut out = vec![0.0f32; n];

    let mut flat =
        CompressedAllreduce::new(workers, n, CompressionKind::OneBit);
    let r_flat = b.run(
        &format!("compressed_allreduce (flat) w={workers} n={n}"),
        || {
            black_box(flat.allreduce(&inputs, &mut out));
        },
    );
    println!("{}", r_flat.report());
    hier_json.push(&r_flat);

    for group in [2usize, 4] {
        let mut hier = HierarchicalAllreduce::new(
            workers,
            n,
            CompressionKind::OneBit,
            group,
        );
        let r_h = b.run(
            &format!(
                "hierarchical_allreduce g={group} w={workers} n={n}"
            ),
            || {
                black_box(hier.allreduce(&inputs, &mut out));
            },
        );
        let sp = r_h.speedup_over(&r_flat);
        println!("{}  => {sp:.2}x vs flat", r_h.report());
        hier_json.push_with(
            &r_h,
            &[("group_size", group as f64), ("speedup_vs_flat", sp)],
        );
    }

    let mut piped = HierarchicalAllreduce::with_options(
        workers,
        n,
        CompressionKind::OneBit,
        4,
        AllreducePath::Pipelined,
        default_threads(),
    );
    let r_p = b.run(
        &format!(
            "hierarchical_allreduce (pipelined) g=4 w={workers} n={n}"
        ),
        || {
            black_box(piped.allreduce(&inputs, &mut out));
        },
    );
    let sp_p = r_p.speedup_over(&r_flat);
    println!("{}  => {sp_p:.2}x vs flat", r_p.report());
    hier_json.push_with(
        &r_p,
        &[("group_size", 4.0), ("speedup_vs_flat", sp_p)],
    );

    let mut flat_piped = CompressedAllreduce::with_options(
        workers,
        n,
        CompressionKind::OneBit,
        AllreducePath::Pipelined,
        default_threads(),
    );
    let r_fp = b.run(
        &format!("compressed_allreduce (pipelined) w={workers} n={n}"),
        || {
            black_box(flat_piped.allreduce(&inputs, &mut out));
        },
    );
    let sp_fp = r_fp.speedup_over(&r_flat);
    println!("{}  => {sp_fp:.2}x vs flat barrier", r_fp.report());
    hier_json.push_with(&r_fp, &[("speedup_vs_flat", sp_fp)]);
    hier_json.flush();
}
