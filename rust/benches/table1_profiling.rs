//! Bench/repro: Table 1 — step-time breakdown and allreduce%% across the
//! paper's cluster configurations (netsim model vs the paper's numbers).
//!
//!     cargo bench --bench table1_profiling

fn main() {
    onebit_adam::repro::timing::table1().expect("table1");
    onebit_adam::repro::timing::volume().expect("volume");
}
