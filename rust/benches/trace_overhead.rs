//! Tracing-overhead bench: the subsystem's core promise is that it
//! disappears when off.  Two contracts are asserted here, not on a
//! dashboard:
//!
//! 1. **disabled cost < 1% of step time** — a disarmed span is one
//!    relaxed atomic load and a branch; measured per call and scaled by
//!    the number of instrumentation points an instrumented 1-bit Adam
//!    compression step actually crosses (counted from a live capture,
//!    with a 4× safety margin for the gate checks that record nothing).
//! 2. **enabled delta** (full mode only; smoke's single sample is
//!    noise) — recording into the ring keeps the step within 15% of
//!    the untraced step.
//!
//! Results land in the repo-root `BENCH_trace.json`
//! (`OBADAM_BENCH_SMOKE=1` runs single-sample smoke passes in CI).

use onebit_adam::optim::{DistOptimizer, OneBitAdam, OneBitAdamConfig};
use onebit_adam::trace::{self, SpanKind};
use onebit_adam::util::bench::{black_box, smoke_mode, BenchJson, Bencher};
use onebit_adam::util::prng::Rng;

const WORKERS: usize = 8;
const ELEMENTS: usize = 1 << 16;
const CALLS: usize = 4096;

fn main() {
    let b = Bencher::from_env();
    let mut json = BenchJson::new_in("trace_overhead", "BENCH_trace.json");
    let smoke = smoke_mode();

    // ---- the disarmed instrumentation point --------------------------------
    assert!(!trace::is_enabled(), "bench must start with tracing off");
    let r_off_call = b.run("disabled_span_x4096", || {
        for i in 0..CALLS {
            black_box(trace::span_aux(SpanKind::Compress, i as u64));
        }
    });
    println!("{}", r_off_call.report());
    let per_call_ns = r_off_call.median_ns() / CALLS as f64;

    // ---- the step it must not perturb --------------------------------------
    let cfg = OneBitAdamConfig {
        warmup_steps: Some(0),
        ..Default::default()
    };
    let mut opt = OneBitAdam::new(WORKERS, vec![0.1; ELEMENTS], cfg);
    let base = Rng::new(47);
    let grads: Vec<Vec<f32>> = (0..WORKERS)
        .map(|i| base.fork(i as u64).normal_vec(ELEMENTS, 1.0))
        .collect();
    let r_untraced = b.run(
        &format!("onebit_step_untraced w={WORKERS} n={ELEMENTS}"),
        || {
            black_box(opt.step(&grads, 1e-3));
        },
    );
    println!("{}", r_untraced.report());

    // Count the instrumentation points one compression step crosses.
    trace::enable_with_capacity(1 << 16);
    opt.step(&grads, 1e-3);
    let events_per_step = trace::take().len();
    trace::clear();
    assert!(events_per_step > 0, "step produced no trace events");

    // ---- the recording step -------------------------------------------------
    trace::enable_with_capacity(1 << 16);
    let r_traced = b.run(
        &format!("onebit_step_traced w={WORKERS} n={ELEMENTS}"),
        || {
            black_box(opt.step(&grads, 1e-3));
        },
    );
    trace::disable();
    trace::clear();
    println!("{}", r_traced.report());

    // ---- contracts ----------------------------------------------------------
    // 4×: every span is ~2 gate checks (open + drop) and instrumented
    // code paths also check gates that record nothing this step.
    let disabled_step_ns = 4.0 * events_per_step as f64 * per_call_ns;
    let step_ns = r_untraced.median_ns();
    let overhead_fraction = disabled_step_ns / step_ns;
    println!(
        "disabled: {per_call_ns:.2} ns/call x {events_per_step} points \
         (x4 margin) = {disabled_step_ns:.0} ns \
         = {:.4}% of the {step_ns:.0} ns step",
        overhead_fraction * 100.0
    );
    assert!(
        overhead_fraction < 0.01,
        "disabled tracing costs {:.3}% of step time (budget 1%)",
        overhead_fraction * 100.0
    );
    let enabled_ratio = r_traced.median_ns() / step_ns;
    println!("enabled: {enabled_ratio:.3}x of the untraced step");
    if !smoke {
        assert!(
            enabled_ratio <= 1.15,
            "recording perturbs the step by {:.1}% (budget 15%)",
            (enabled_ratio - 1.0) * 100.0
        );
    }

    json.push_with(
        &r_untraced,
        &[
            ("disabled_per_call_ns", per_call_ns),
            ("events_per_step", events_per_step as f64),
            ("disabled_overhead_fraction", overhead_fraction),
            ("enabled_ratio", enabled_ratio),
        ],
    );
    json.push(&r_off_call);
    json.push(&r_traced);
    json.flush();
}
