//! Overlapped-step pipeline bench: the bucketed schedule
//! ([`onebit_adam::comm::overlap::OverlapPipeline`]) running the full
//! 1-bit Adam compression step at the acceptance point of 8 workers ×
//! 1M elements — overlapped vs synchronous on the *same* bucketization
//! and fixed codec assignment, so the two runs are bit-identical and
//! the time delta is pure scheduling.
//!
//! Three contracts are asserted right here, not on a dashboard:
//!
//! 1. **bit-identity** — params, per-step `CommStats`, and the carried
//!    EC state of the overlapped trajectory equal the synchronous one;
//! 2. **the 0.9× regression gate** — overlapped median step time ≤
//!!   0.9 × the synchronous (compute + comm) median (full mode only;
//!    single-sample smoke timings stay informational);
//! 3. **ledger reconciliation** — the merged step `CommStats` equals
//!    the per-bucket sum reported by the pipeline.
//!
//! Results land in the repo-root `BENCH_overlap.json`, including the
//! per-bucket codec decisions and measured wire volumes plus the
//! `netsim::collectives::overlapped_step_time` analytic twin's
//! prediction (`OBADAM_BENCH_SMOKE=1` runs single-sample smoke passes
//! in CI).

use onebit_adam::comm::overlap::{
    BucketCodecPolicy, LinkEstimate, OverlapConfig, OverlapPipeline,
};
use onebit_adam::comm::{CommStats, CommTopology};
use onebit_adam::compress::CompressionKind;
use onebit_adam::netsim::collectives::overlapped_step_time;
use onebit_adam::netsim::NetworkModel;
use onebit_adam::optim::{DistOptimizer, OneBitAdam, OneBitAdamConfig};
use onebit_adam::util::bench::{black_box, smoke_mode, BenchJson, Bencher};
use onebit_adam::util::prng::Rng;

const WORKERS: usize = 8;
const ELEMENTS: usize = 1 << 20;
const N_BUCKETS: usize = 8;

fn codec_width(kind: CompressionKind) -> f64 {
    match kind {
        CompressionKind::None => 32.0,
        CompressionKind::NBit(b) => b as f64,
        CompressionKind::OneBit => 1.0,
    }
}

fn codec_name(kind: CompressionKind) -> String {
    match kind {
        CompressionKind::None => "fp32".to_string(),
        CompressionKind::NBit(b) => format!("{b}bit"),
        CompressionKind::OneBit => "1bit".to_string(),
    }
}

fn optimizer(overlapped: bool) -> OneBitAdam {
    let cfg = OneBitAdamConfig {
        warmup_steps: Some(0),
        compression: CompressionKind::OneBit,
        topology: CommTopology::Flat,
        overlap: Some(OverlapConfig {
            n_buckets: N_BUCKETS,
            policy: BucketCodecPolicy::Fixed,
            overlapped,
        }),
        ..Default::default()
    };
    OneBitAdam::new(WORKERS, vec![0.1; ELEMENTS], cfg)
}

fn main() {
    let b = Bencher::from_env();
    let mut json = BenchJson::new_in("overlap", "BENCH_overlap.json");
    let smoke = smoke_mode();

    let base = Rng::new(47);
    let grad_sets: Vec<Vec<Vec<f32>>> = (0..3)
        .map(|s| {
            (0..WORKERS)
                .map(|i| {
                    base.fork((s * WORKERS + i) as u64)
                        .normal_vec(ELEMENTS, 1.0)
                })
                .collect()
        })
        .collect();

    // ---- bit-identity: overlapped trajectory == synchronous ----------------
    let mut ovl = optimizer(true);
    let mut syn = optimizer(false);
    let check_steps = if smoke { 2 } else { 4 };
    for step in 0..check_steps {
        let grads = &grad_sets[step % grad_sets.len()];
        let so = ovl.step(grads, 1e-3);
        let ss = syn.step(grads, 1e-3);
        assert_eq!(
            so.comm, ss.comm,
            "step {step}: overlapped CommStats diverged"
        );
        assert_eq!(
            ovl.params(),
            syn.params(),
            "step {step}: overlapped params diverged"
        );
        // the merged step ledger is exactly the per-bucket sum
        let mut sum = CommStats::default();
        for s in ovl.overlap_pipeline().unwrap().bucket_stats() {
            sum.merge(*s);
        }
        assert_eq!(so.comm, sum, "step {step}: bucket ledger drifted");
    }
    assert_eq!(
        ovl.overlap_pipeline().unwrap().export_errors(),
        syn.overlap_pipeline().unwrap().export_errors(),
        "EC state diverged between schedules"
    );
    println!(
        "bit-identity: {check_steps} overlapped steps == synchronous \
         (params, CommStats, EC state)"
    );

    // ---- step-time: overlapped vs synchronous ------------------------------
    let grads = &grad_sets[0];
    let r_syn = b.run(
        &format!(
            "onebit_step_synchronous w={WORKERS} n={ELEMENTS} nb={N_BUCKETS}"
        ),
        || {
            black_box(syn.step(grads, 1e-3));
        },
    );
    let r_ovl = b.run(
        &format!(
            "onebit_step_overlapped w={WORKERS} n={ELEMENTS} nb={N_BUCKETS}"
        ),
        || {
            black_box(ovl.step(grads, 1e-3));
        },
    );
    println!("{}", r_syn.report());
    println!("{}", r_ovl.report());

    // Comm-only leg: the same bucketed collectives with a trivial
    // produce (staging copy), synchronous schedule — isolates the
    // compress + exchange cost so the compute share can be derived.
    let cfg = OverlapConfig {
        n_buckets: N_BUCKETS,
        policy: BucketCodecPolicy::Fixed,
        overlapped: false,
    };
    let mut pipe = OverlapPipeline::build(
        &cfg,
        CommTopology::Flat,
        WORKERS,
        ELEMENTS,
        CompressionKind::OneBit,
        None,
    );
    let mut out = vec![0.0f32; ELEMENTS];
    let r_comm = b.run(
        &format!("bucketed_allreduce_only w={WORKERS} n={ELEMENTS}"),
        || {
            black_box(pipe.allreduce(grads, &mut out));
        },
    );
    println!("{}", r_comm.report());

    let t_syn = r_syn.median_ns();
    let t_ovl = r_ovl.median_ns();
    let t_comm = r_comm.median_ns().min(t_syn);
    let t_compute = (t_syn - t_comm).max(0.0);
    let ratio = t_ovl / t_syn;

    // Analytic twin: uniform buckets through the two-stage pipeline
    // recurrence — the modeled floor the measured overlap approaches.
    let nb = N_BUCKETS;
    let uniform = |total: f64| -> Vec<f64> {
        (0..nb).map(|_| total / nb as f64).collect()
    };
    let twin = overlapped_step_time(&uniform(t_compute), &uniform(t_comm));
    let ideal = t_compute.max(t_comm);
    println!(
        "overlap: {ratio:.3}x of synchronous (twin predicts \
         {:.3}x, ideal max(compute, comm) floor {:.3}x)",
        twin / t_syn,
        ideal / t_syn
    );

    // The regression gate (full mode: smoke's single sample is noise).
    if !smoke {
        assert!(
            ratio <= 0.9,
            "overlapped step not ≤ 0.9x synchronous: {t_ovl:.0} ns vs \
             {t_syn:.0} ns ({ratio:.3}x)"
        );
    }

    // ---- ledger: per-bucket codec decisions + measured volumes -------------
    let pipeline = ovl.overlap_pipeline().unwrap();
    let mut extras: Vec<(String, f64)> = vec![
        ("n_buckets".to_string(), nb as f64),
        ("ratio_vs_synchronous".to_string(), ratio),
        ("synchronous_median_ns".to_string(), t_syn),
        ("comm_only_median_ns".to_string(), t_comm),
        ("compute_share_ns".to_string(), t_compute),
        ("netsim_twin_predicted_ns".to_string(), twin),
        ("ideal_overlap_floor_ns".to_string(), ideal),
    ];
    for (k, (kind, stats)) in pipeline
        .kinds()
        .iter()
        .zip(pipeline.bucket_stats().iter())
        .enumerate()
    {
        println!(
            "  bucket {k}: {} ({} payload B/gpu)",
            codec_name(*kind),
            stats.total_per_gpu()
        );
        extras.push((format!("bucket_{k}_codec_bits"), codec_width(*kind)));
        extras.push((
            format!("bucket_{k}_payload_bytes_per_gpu"),
            stats.total_per_gpu() as f64,
        ));
    }
    let borrowed: Vec<(&str, f64)> =
        extras.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    json.push_with(&r_ovl, &borrowed);
    json.push(&r_syn);
    json.push(&r_comm);

    // ---- adaptive policy: decisions on a modeled slow link -----------------
    // No timing — just record what the policy picks at this bucket size
    // on the paper's Ethernet cluster vs a fat link, so the ledger shows
    // the codec choice moving with bandwidth.
    for (label, net) in [
        ("ethernet", NetworkModel::ethernet()),
        ("infiniband", NetworkModel::infiniband()),
    ] {
        let est = LinkEstimate::from_netsim(&net);
        let cfg = OverlapConfig {
            n_buckets: N_BUCKETS,
            policy: BucketCodecPolicy::Adaptive(est),
            overlapped: true,
        };
        let p = OverlapPipeline::build(
            &cfg,
            CommTopology::Flat,
            WORKERS,
            ELEMENTS,
            CompressionKind::OneBit,
            None,
        );
        let names: Vec<String> =
            p.kinds().iter().map(|k| codec_name(*k)).collect();
        println!(
            "adaptive policy on {label}: buckets -> [{}]",
            names.join(", ")
        );
    }

    json.flush();
}
