//! Bench/repro: Figure 9 — compression-stage speedup under `tc`-shaped
//! bandwidth from 50 Mbit to 3 Gbit at 256 GPUs.
//!
//!     cargo bench --bench fig9_bandwidth_sweep

fn main() {
    onebit_adam::repro::timing::fig9().expect("fig9");
}
