//! End-to-end pre-training driver — the headline validation run.
//!
//! Trains a transformer LM (default `lm-med`, ~6.9M params; pass
//! `--size lm-100m` after `make artifacts-100m` for the ~91M-parameter
//! configuration) for several hundred steps on the synthetic corpus, with
//! uncompressed Adam and with 1-bit Adam, through the full three-layer
//! stack: L1 Pallas kernels + L2 JAX fwd/bwd lowered to HLO, executed from
//! Rust over PJRT; L3 owns the data-parallel loop, the byte-accurate
//! compressed_allreduce, and the calibrated virtual cluster clock.
//!
//!     cargo run --release --example bert_pretrain -- \
//!         [--size lm-med] [--steps 300] [--workers 4] [--gpus 64] \
//!         [--out results]
//!
//! Writes loss curves to `results/bert_pretrain_<opt>.csv` and prints the
//! sample-wise parity + simulated time-wise speedup (Figure 4 shape).

use std::rc::Rc;

use onebit_adam::coordinator::{
    GradSource,
    train, LmSource, LrSchedule, TimingModel, TrainOptions,
};
use onebit_adam::netsim::{ComputeModel, NetworkModel};
use onebit_adam::optim::backend::AdamHyper;
use onebit_adam::optim::onebit_adam::{OneBitAdam, OneBitAdamConfig};
use onebit_adam::optim::{Adam, DistOptimizer};
use onebit_adam::runtime::Runtime;
use onebit_adam::util::cli::Args;
use onebit_adam::util::prng::Rng;

fn main() -> onebit_adam::Result<()> {
    let args = Args::from_env();
    let size = args.get_or("size", "lm-med").to_string();
    let steps = args.usize_or("steps", 300)?;
    let workers = args.usize_or("workers", 4)?;
    let gpus = args.usize_or("gpus", 64)?;
    let out = args.get_or("out", "results").to_string();
    let artifacts = args.get_or("artifacts", "artifacts").to_string();

    let rt = Rc::new(Runtime::load(&artifacts)?);
    let hyper = AdamHyper { beta2: 0.97, ..AdamHyper::default() };
    let schedule = LrSchedule::LinearWarmupExpDecay {
        peak: 6e-4,
        warmup: steps / 10,
        every: (steps / 16).max(1),
        decay: 0.92,
    };
    let timing = TimingModel {
        net: NetworkModel::ethernet(),
        compute: ComputeModel::bert_large_v100(),
        n_gpus: gpus,
        grad_accum: 4,
        params_override: Some(340_000_000), // charge BERT-Large traffic
    };

    let mut logs = Vec::new();
    for compressed in [false, true] {
        let mut src = LmSource::new(rt.clone(), &size, workers, 17)?;
        let dim = src.dim();
        println!(
            "=== {} on {size} ({:.1}M params, {workers} workers, {steps} steps, \
             batch {}x{} tokens/worker) ===",
            if compressed { "1-bit Adam" } else { "Adam" },
            dim as f64 / 1e6,
            src.batch(),
            src.seq(),
        );
        let init = Rng::new(23).normal_vec(dim, 0.02);
        let mut opt: Box<dyn DistOptimizer> = if compressed {
            Box::new(OneBitAdam::new(
                workers,
                init,
                OneBitAdamConfig {
                    warmup_steps: None, // the paper's auto-switch criterion
                    min_warmup_steps: steps / 5,
                    hyper,
                    ..Default::default()
                },
            ))
        } else {
            Box::new(Adam::new(workers, init).with_hyper(hyper))
        };
        let opts = TrainOptions {
            steps,
            schedule,
            timing: Some(timing.clone()),
            log_every: (steps / 10).max(1),
        };
        let log = train(opt.as_mut(), &mut src, &opts)?;
        log.write_csv(format!("{out}/bert_pretrain_{}.csv", log.name))?;
        logs.push(log);
    }

    let adam = &logs[0];
    let onebit = &logs[1];
    println!("\n================ summary ================");
    println!(
        "{:<22} {:>12} {:>12}",
        "", "Adam", "1-bit Adam"
    );
    println!(
        "{:<22} {:>12.4} {:>12.4}",
        "final loss (tail-20)",
        adam.tail_loss(20).unwrap(),
        onebit.tail_loss(20).unwrap()
    );
    println!(
        "{:<22} {:>12} {:>12}",
        "warmup steps",
        adam.records.len(),
        onebit.warmup_steps()
    );
    println!(
        "{:<22} {:>9.1} MB {:>9.1} MB",
        "comm volume/GPU",
        adam.total_comm_bytes() as f64 / 1e6,
        onebit.total_comm_bytes() as f64 / 1e6
    );
    println!(
        "{:<22} {:>11.0}s {:>11.0}s",
        "sim time (64-GPU Eth)",
        adam.sim_time(),
        onebit.sim_time()
    );
    println!(
        "\nsample-wise loss gap: {:+.4}   volume reduction: {:.1}x   \
         time-wise speedup: {:.2}x",
        onebit.tail_loss(20).unwrap() - adam.tail_loss(20).unwrap(),
        onebit.volume_reduction_vs(adam),
        adam.sim_time() / onebit.sim_time()
    );
    Ok(())
}
