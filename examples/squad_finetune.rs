//! Fine-tuning scenario (the paper's SQuAD experiment, §7.1): start from a
//! pre-trained checkpoint, fine-tune on a *different* synthetic corpus with
//! 1-bit Adam using the paper's 400/1848 ≈ 21.6% warmup ratio, and compare
//! final quality against uncompressed Adam.  Throughput is reported on the
//! 32-GPU InfiniBand configuration of Figure 5(c).
//!
//!     cargo run --release --example squad_finetune

use std::rc::Rc;

use onebit_adam::coordinator::{
    GradSource,
    train, LmSource, LrSchedule, TimingModel, TrainOptions,
};
use onebit_adam::netsim::{ComputeModel, NetworkModel};
use onebit_adam::optim::backend::AdamHyper;
use onebit_adam::optim::onebit_adam::{OneBitAdam, OneBitAdamConfig};
use onebit_adam::optim::{Adam, DistOptimizer};
use onebit_adam::runtime::Runtime;
use onebit_adam::util::cli::Args;
use onebit_adam::util::prng::Rng;

fn main() -> onebit_adam::Result<()> {
    let args = Args::from_env();
    let workers = args.usize_or("workers", 4)?;
    let pretrain_steps = args.usize_or("pretrain-steps", 300)?;
    let ft_steps = args.usize_or("steps", 185)?; // 1848 / 10
    let artifacts = args.get_or("artifacts", "artifacts").to_string();

    let rt = Rc::new(Runtime::load(&artifacts)?);
    let hyper = AdamHyper { beta2: 0.97, ..AdamHyper::default() };

    // ---- "HuggingFace checkpoint": quick Adam pre-train on corpus A ----
    println!("pre-training the checkpoint ({pretrain_steps} steps)...");
    let mut src = LmSource::new(rt.clone(), "lm-tiny", workers, 5)?;
    let dim = src.dim();
    let mut pre: Box<dyn DistOptimizer> = Box::new(
        Adam::new(workers, Rng::new(9).normal_vec(dim, 0.02))
            .with_hyper(hyper),
    );
    let opts = TrainOptions {
        steps: pretrain_steps,
        schedule: LrSchedule::Constant(1e-3),
        timing: None,
        log_every: 0,
    };
    let pre_log = train(pre.as_mut(), &mut src, &opts)?;
    println!("checkpoint loss: {:.4}", pre_log.tail_loss(20).unwrap());
    let checkpoint = pre.params().to_vec();

    // ---- fine-tune on corpus B (different transition structure) --------
    let timing = TimingModel {
        net: NetworkModel::infiniband(),
        compute: ComputeModel::bert_large_squad(),
        n_gpus: 32,
        grad_accum: 1,
        params_override: Some(340_000_000),
    };
    let mut results = Vec::new();
    for compressed in [false, true] {
        let mut src = LmSource::new(rt.clone(), "lm-tiny", workers, 5555)?;
        // paper: first 400 of 1848 steps are warmup => 21.6%
        let warmup = ft_steps * 400 / 1848;
        let mut opt: Box<dyn DistOptimizer> = if compressed {
            Box::new(OneBitAdam::new(
                workers,
                checkpoint.clone(),
                OneBitAdamConfig {
                    warmup_steps: Some(warmup),
                    hyper,
                    ..Default::default()
                },
            ))
        } else {
            Box::new(
                Adam::new(workers, checkpoint.clone()).with_hyper(hyper),
            )
        };
        let opts = TrainOptions {
            steps: ft_steps,
            schedule: LrSchedule::Constant(3e-4), // HF's 3e-5 scaled
            timing: Some(timing.clone()),
            log_every: 0,
        };
        let log = train(opt.as_mut(), &mut src, &opts)?;
        println!(
            "{:<10}  fine-tuned loss {:.4}  sim time {:.1}s  comm {:.1} MB",
            log.name,
            log.tail_loss(15).unwrap(),
            log.sim_time(),
            log.total_comm_bytes() as f64 / 1e6
        );
        results.push(log);
    }
    let gap = results[1].tail_loss(15).unwrap()
        - results[0].tail_loss(15).unwrap();
    println!(
        "\nquality gap (compressed − uncompressed): {gap:+.4}  \
         (paper: F1 93.32 vs 93.33 — parity)"
    );
    println!(
        "fine-tune sim-time speedup: {:.2}x (paper: up to 2.9x end-to-end)",
        results[0].sim_time() / results[1].sim_time()
    );
    Ok(())
}
