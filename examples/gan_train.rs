//! Adversarial training demo (the paper's DCGAN experiment, Figure 8):
//! a tiny GAN on the synthetic face-mode data, trained with Adam and with
//! 1-bit Adam (40% warmup, matched low lr — see EXPERIMENTS.md for the
//! stability envelope of the tiny-GAN proxy).
//!
//!     cargo run --release --example gan_train

use std::rc::Rc;

use onebit_adam::coordinator::gan::GanTrainer;
use onebit_adam::optim::backend::AdamHyper;
use onebit_adam::optim::onebit_adam::{OneBitAdam, OneBitAdamConfig};
use onebit_adam::optim::{Adam, DistOptimizer};
use onebit_adam::runtime::Runtime;
use onebit_adam::util::cli::Args;
use onebit_adam::util::prng::Rng;

fn main() -> onebit_adam::Result<()> {
    let args = Args::from_env();
    let steps = args.usize_or("steps", 300)?;
    let workers = args.usize_or("workers", 4)?;
    let artifacts = args.get_or("artifacts", "artifacts").to_string();
    let rt = Rc::new(Runtime::load(&artifacts)?);

    let spec = rt.manifest().get("gan_d_step").unwrap().clone();
    let dp = spec.inputs[0].elements();
    let gp = spec.inputs[1].elements();
    let hyper = AdamHyper { beta2: 0.9, ..AdamHyper::default() };

    for (label, compressed) in [("Adam", false), ("1-bit Adam", true)] {
        let warmup = steps * 2 / 5;
        let mk = |init: Vec<f32>| -> Box<dyn DistOptimizer> {
            if compressed {
                Box::new(OneBitAdam::new(
                    workers,
                    init,
                    OneBitAdamConfig {
                        warmup_steps: Some(warmup),
                        hyper,
                        ..Default::default()
                    },
                ))
            } else {
                Box::new(Adam::new(workers, init).with_hyper(hyper))
            }
        };
        let mut d_opt = mk(Rng::new(5).normal_vec(dp, 0.02));
        let mut g_opt = mk(Rng::new(6).normal_vec(gp, 0.02));
        let mut trainer = GanTrainer::new(rt.clone(), workers, 31)?;
        let mut comm = 0usize;
        println!("=== {label} ===");
        for step in 0..steps {
            let rec =
                trainer.step(d_opt.as_mut(), g_opt.as_mut(), step, 5e-5, 5e-5)?;
            comm += rec.comm_bytes;
            if step % (steps / 6).max(1) == 0 {
                println!(
                    "  step {:>4}  D {:.4}  G {:.4}",
                    step, rec.d_loss, rec.g_loss
                );
            }
        }
        println!("  total comm: {:.2} MB/GPU\n", comm as f64 / 1e6);
    }
    println!(
        "healthy adversarial equilibrium keeps D near ln(2)·2 ≈ 1.39 and G \
         near ln(2) ≈ 0.69 — both optimizers should hover in that region."
    );
    Ok(())
}
