//! Bandwidth sweep (the supplementary Figure 9 scenario, as an API demo):
//! how does the warmup vs compression stage step time move as the
//! interconnect degrades from 100 Gb InfiniBand to 50 Mb shaped Ethernet?
//!
//!     cargo run --release --example bandwidth_sweep [-- --gpus 256]

use onebit_adam::metrics::Table;
use onebit_adam::netsim::collectives::{
    compressed_allreduce_time, fp16_allreduce_time,
};
use onebit_adam::netsim::{ComputeModel, NetworkModel};
use onebit_adam::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let gpus = args.usize_or("gpus", 256).unwrap_or(256);
    let params = args.usize_or("params", 340_000_000).unwrap_or(340_000_000);
    let compute = ComputeModel::bert_large_v100();

    println!(
        "step time vs interconnect — {gpus} GPUs, {}M params",
        params / 1_000_000
    );
    let mut t = Table::new(&[
        "network", "adam step", "1bit step", "speedup", "adam samples/s",
        "1bit samples/s",
    ]);
    let nets: Vec<(String, NetworkModel)> = vec![
        ("infiniband-100G".into(), NetworkModel::infiniband()),
        ("ethernet-40G(4.1eff)".into(), NetworkModel::ethernet()),
        ("tcp-10G".into(), NetworkModel::tcp(10.0)),
        ("tcp-1G".into(), NetworkModel::tcp(1.0)),
        ("shaped-200Mbit".into(), NetworkModel::shaped_ethernet(200e6)),
        ("shaped-50Mbit".into(), NetworkModel::shaped_ethernet(50e6)),
    ];
    for (name, net) in nets {
        let adam =
            compute.step_compute(1) + fp16_allreduce_time(&net, gpus, params);
        let onebit = compute.step_compute(1)
            + compressed_allreduce_time(&net, gpus, params);
        let batch = (gpus * 16) as f64;
        t.row(&[
            name,
            format!("{adam:.2}s"),
            format!("{onebit:.2}s"),
            format!("{:.2}x", adam / onebit),
            format!("{:.0}", batch / adam),
            format!("{:.0}", batch / onebit),
        ]);
    }
    println!("{}", t.render());
}
