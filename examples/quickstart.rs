//! Quickstart: train a tiny causal LM with 1-bit Adam on 4 simulated
//! workers, entirely through the three-layer stack (AOT HLO via PJRT —
//! no Python at runtime).
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Prints the loss curve, the warmup→compression switch, and the measured
//! communication-volume reduction vs uncompressed Adam.

use std::rc::Rc;

use onebit_adam::coordinator::{
    GradSource,train, LmSource, LrSchedule, TrainOptions};
use onebit_adam::optim::backend::AdamHyper;
use onebit_adam::optim::onebit_adam::{OneBitAdam, OneBitAdamConfig};
use onebit_adam::optim::{Adam, DistOptimizer};
use onebit_adam::runtime::Runtime;
use onebit_adam::util::prng::Rng;

fn main() -> onebit_adam::Result<()> {
    let rt = Rc::new(Runtime::load("artifacts")?);
    println!("PJRT platform: {}", rt.platform());

    let workers = 4;
    let steps = 400;
    // Short-run scaling: β₂ = 0.97 so the variance stabilizes within the
    // run (the paper's 0.999 needs tens of thousands of steps; DESIGN.md).
    let hyper = AdamHyper { beta2: 0.97, ..AdamHyper::default() };
    let schedule = LrSchedule::LinearWarmupExpDecay {
        peak: 1e-3,
        warmup: 40,
        every: 50,
        decay: 0.95,
    };

    // --- uncompressed Adam baseline -------------------------------------
    let mut src = LmSource::new(rt.clone(), "lm-tiny", workers, 1)?;
    let dim = src.dim();
    let init = Rng::new(7).normal_vec(dim, 0.02);
    let mut adam: Box<dyn DistOptimizer> =
        Box::new(Adam::new(workers, init.clone()).with_hyper(hyper));
    let opts = TrainOptions { steps, schedule, timing: None, log_every: 100 };
    let adam_log = train(adam.as_mut(), &mut src, &opts)?;

    // --- 1-bit Adam with the auto-switch criterion ----------------------
    let mut src = LmSource::new(rt.clone(), "lm-tiny", workers, 1)?;
    let mut onebit: Box<dyn DistOptimizer> = Box::new(OneBitAdam::new(
        workers,
        init,
        OneBitAdamConfig {
            warmup_steps: None, // auto-switch when ‖v‖ stabilizes
            min_warmup_steps: 80,
            hyper,
            ..Default::default()
        },
    ));
    let onebit_log = train(onebit.as_mut(), &mut src, &opts)?;

    println!("\n                 {:>12} {:>12}", "Adam", "1-bit Adam");
    println!(
        "final loss       {:>12.4} {:>12.4}",
        adam_log.tail_loss(20).unwrap(),
        onebit_log.tail_loss(20).unwrap()
    );
    println!(
        "comm volume      {:>9.2} MB {:>9.2} MB",
        adam_log.total_comm_bytes() as f64 / 1e6,
        onebit_log.total_comm_bytes() as f64 / 1e6
    );
    println!(
        "warmup steps     {:>12} {:>12}",
        adam_log.records.len(),
        onebit_log.warmup_steps()
    );
    println!(
        "\nvolume reduction: {:.1}x with matching convergence — the paper's \
         headline, on your CPU.",
        onebit_log.volume_reduction_vs(&adam_log)
    );
    Ok(())
}
