//! Run the paper's 1-bit compressed allreduce over **real TCP sockets**
//! and watch the measured wire bytes land on the netsim model's
//! prediction.
//!
//!     cargo run --release --example tcp_allreduce
//!
//! Eight ranks (one OS thread each) build a full loopback mesh — one
//! connection per rank pair, `TCP_NODELAY` on — and push a 1M-element
//! momentum tensor through the Figure-3 collective three ways: fp32
//! payloads, 1-bit payloads, and the two-level hierarchical topology
//! (1-bit between node leaders only).  Every message is a framed,
//! checksummed `transport::frame` payload; the output is bit-identical
//! to the in-process `CompressedAllreduce` reference (property-tested in
//! the crate), so what changes on the wire is *only* the bytes.

use onebit_adam::comm::CompressedAllreduce;
use onebit_adam::compress::CompressionKind;
use onebit_adam::netsim::collectives::calibrate;
use onebit_adam::transport::{TransportBackend, TransportCollective};
use onebit_adam::util::prng::Rng;

fn main() {
    let workers = 8usize;
    let n = 1usize << 20;
    println!(
        "building loopback TCP mesh: {workers} ranks, {} pairs",
        workers * (workers - 1) / 2
    );
    let base = Rng::new(7);
    let inputs: Vec<Vec<f32>> = (0..workers)
        .map(|i| base.fork(i as u64).normal_vec(n, 1.0))
        .collect();
    let mut out = vec![0.0f32; n];

    let mut fp32_gross = 0usize;
    for kind in [CompressionKind::None, CompressionKind::OneBit] {
        let mut wire = TransportCollective::new(
            TransportBackend::Tcp,
            workers,
            n,
            kind,
        )
        .expect("loopback mesh");
        let t0 = std::time::Instant::now();
        let comm = wire.allreduce(&inputs, &mut out);
        let dt = t0.elapsed();
        let ts = wire.last_stats();
        let cal = calibrate(kind, workers, n, &ts);
        println!(
            "\n{kind:?}: {dt:?} for one step over TCP\n  payload/gpu: {} B \
             (netsim predicts {} — {})\n  gross on the wire: {} B across \
             {} frames ({} B frame overhead)",
            comm.total_per_gpu(),
            cal.predicted_payload_per_gpu,
            if cal.agrees() { "exact match" } else { "MISMATCH" },
            ts.gross_total(),
            ts.frames_sent,
            cal.header_overhead_bytes(),
        );
        if kind == CompressionKind::None {
            fp32_gross = ts.gross_total();
        } else {
            println!(
                "  measured volume reduction vs fp32: {:.1}x",
                fp32_gross as f64 / ts.gross_total() as f64
            );
        }
        // transport invariance: the wire result equals the in-process
        // reference bit for bit
        let mut reference = CompressedAllreduce::new(workers, n, kind);
        let mut out_ref = vec![0.0f32; n];
        reference.allreduce(&inputs, &mut out_ref);
        assert_eq!(out, out_ref, "wire result != in-process reference");
        println!("  bit-identical to the in-process engine ✓");
    }

    // Two-level topology: 1-bit only between the two node leaders.
    let mut hier = TransportCollective::with_topology(
        TransportBackend::Tcp,
        workers,
        n,
        CompressionKind::OneBit,
        4,
    )
    .expect("loopback mesh");
    let comm = hier.allreduce(&inputs, &mut out);
    let ts = hier.last_stats();
    println!(
        "\nhierarchical (2 nodes × 4): leader-exchange payload/gpu {} B, \
         intra-node fp32 traffic {} B gross",
        comm.total_per_gpu(),
        ts.gross_intra_bytes,
    );
}
